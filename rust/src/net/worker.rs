//! The deployment-plane LLM Node: connects to a `net::server` Aggregator,
//! pulls the task spec, and serves rounds until told to shut down
//! (paper §4.1 / Algorithm 1 L.12–27, over a real socket).
//!
//! Workers are **stateless**: every assignment carries the client's stream
//! cursors and KeepOpt moments, and every push returns them advanced. A
//! worker can therefore crash, be killed, or reconnect to a restarted
//! server without any local persistence — the Aggregator's checkpoint is
//! the only durable state. The local round itself is the *same code* the
//! in-process federation runs (`ClientNode::run_local_round`), which is
//! what makes a localhost fleet bit-identical to `Federation::run`.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::federation::{bind_client_streams, build_data};
use crate::coordinator::ClientNode;
use crate::data::source::DataSource;
use crate::net::proto::{self, Heartbeat, Join, Msg, TaskSpec, UpdatePush, PROTO_VERSION};
use crate::runtime::{ModelRuntime, Runtime};

/// Worker knobs (the test harness uses the fault hook; the CLI only the
/// name/model fields).
#[derive(Clone, Default)]
pub struct WorkerOpts {
    /// Display name sent in the Join (logs only).
    pub name: String,
    /// Preloaded model runtime — the loopback harness shares one compiled
    /// model across the fleet; `None` loads `spec.model` from artifacts.
    pub model: Option<Arc<ModelRuntime>>,
    /// Test hook: drop the connection (simulating a crash) on receiving
    /// the assignment for this round, before replying.
    pub die_at_round: Option<u64>,
    pub verbose: bool,
}

/// What a worker did during one session.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerReport {
    pub worker_slot: u64,
    pub rounds_served: u64,
    pub updates_pushed: u64,
    /// Set when the `die_at_round` fault hook fired.
    pub aborted_at: Option<u64>,
}

/// Connect to `addr`, join the federation, and serve rounds until the
/// server sends `Shutdown` (or the fault hook fires). Blocking.
pub fn run_worker(addr: &str, opts: WorkerOpts) -> Result<WorkerReport> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    proto::write_msg(
        &mut stream,
        &Msg::Join(Join { proto: PROTO_VERSION, name: opts.name.clone() }),
        false,
    )?;
    let ack = match proto::read_msg(&mut stream)? {
        Msg::JoinAck(a) => a,
        Msg::Reject(r) => bail!("server rejected join: {}", r.reason),
        other => bail!("expected JoinAck, got {:?}", other.kind()),
    };
    ensure!(
        ack.proto == PROTO_VERSION,
        "server speaks photon-net v{}, this worker v{PROTO_VERSION} — upgrade",
        ack.proto
    );
    let spec = ack.spec;
    let model = match &opts.model {
        Some(m) => m.clone(),
        None => {
            let rt = Runtime::cpu()?;
            Arc::new(rt.load_model(&spec.model)?)
        }
    };
    ensure!(
        model.n_params() as u64 == spec.n_params,
        "model {} has {} params, server expects {} — artifact mismatch",
        spec.model,
        model.n_params(),
        spec.n_params
    );
    ensure!(
        spec.islands.len() == spec.n_clients as usize,
        "task spec carries {} island counts for {} clients",
        spec.islands.len(),
        spec.n_clients
    );

    // Build the identical data plane the Aggregator built: same corpus,
    // same partition, same per-client stream binding.
    let data = build_data(
        &spec.corpus,
        spec.n_clients as usize,
        spec.seed,
        model.manifest.config.vocab,
    );
    let seq_width = model.seq_width();
    let schedule = spec.schedule;
    let lr_at = move |t: u64| schedule.lr(t);

    let mut nodes: HashMap<u64, ClientNode> = HashMap::new();
    let mut report =
        WorkerReport { worker_slot: ack.worker_slot, ..WorkerReport::default() };
    if opts.verbose {
        println!(
            "[worker {}] joined session {:#x} as slot {} ({} clients, model {})",
            opts.name, ack.session, ack.worker_slot, spec.n_clients, spec.model
        );
    }

    loop {
        match proto::read_msg(&mut stream)? {
            Msg::RoundAssign(assign) => {
                if opts.die_at_round == Some(assign.round) {
                    // Simulated crash: vanish mid-round without replying.
                    report.aborted_at = Some(assign.round);
                    return Ok(report);
                }
                if assign.session != ack.session {
                    continue; // stale server incarnation
                }
                proto::write_msg(
                    &mut stream,
                    &Msg::Heartbeat(Heartbeat {
                        session: ack.session,
                        round: assign.round,
                    }),
                    false,
                )?;
                for task in &assign.tasks {
                    let node = node_for(
                        &mut nodes, &data, &spec, task.client, seq_width,
                    )?;
                    node.restore_state(&task.state)
                        .with_context(|| format!("restoring client {}", task.client))?;
                    let mut update = node
                        .run_local_round(
                            &model,
                            &assign.global,
                            task.steps,
                            assign.seq_base,
                            &lr_at,
                            spec.opt_state,
                        )
                        .with_context(|| {
                            format!("client {} round {}", task.client, assign.round)
                        })?;
                    // Apply the negotiated update codec (no-op body for the
                    // lossless codecs). Seeded per (round, client) from the
                    // task spec, so the encode is byte-identical to what
                    // the in-process federation computes — the parity
                    // invariant extends to lossy transport. Must run before
                    // `state()` so the error-feedback residual ships back.
                    let seed = crate::compress::transit_seed(
                        spec.seed,
                        assign.round,
                        task.client,
                    );
                    let transit = crate::compress::encode_transit(
                        &spec.codec,
                        &assign.global,
                        &update.params,
                        seed,
                        &mut node.residual,
                    )
                    .with_context(|| {
                        format!("encoding client {} update", task.client)
                    })?;
                    let state = node.state();
                    let body = match transit.body {
                        Some(b) => {
                            // Coded push: the dense params stay home.
                            update.params = Vec::new();
                            Some(b)
                        }
                        None => None,
                    };
                    proto::write_msg(
                        &mut stream,
                        &Msg::UpdatePush(UpdatePush {
                            session: ack.session,
                            round: assign.round,
                            update,
                            body,
                            state,
                        }),
                        spec.compress,
                    )?;
                    report.updates_pushed += 1;
                }
                report.rounds_served += 1;
            }
            Msg::RoundCommit(c) => {
                if opts.verbose {
                    println!(
                        "[worker {}] round {} committed ({} participated, |g| {:.4})",
                        opts.name, c.round, c.participated, c.global_norm
                    );
                }
            }
            Msg::Shutdown => return Ok(report),
            Msg::Reject(r) => bail!("server rejected mid-session: {}", r.reason),
            other => bail!("unexpected {:?} from server", other.kind()),
        }
    }
}

/// Lazily build the node for `client` with the spec's island arity. The
/// initial binding state is irrelevant (every assignment restores the
/// authoritative cursors) but the *structure* — island and bucket arity —
/// must match the Aggregator's, which `bind_client_streams` guarantees.
fn node_for<'a>(
    nodes: &'a mut HashMap<u64, ClientNode>,
    data: &DataSource,
    spec: &TaskSpec,
    client: u64,
    seq_width: usize,
) -> Result<&'a mut ClientNode> {
    ensure!(
        (client as usize) < spec.n_clients as usize,
        "assignment names client {client}, spec has {} clients",
        spec.n_clients
    );
    if !nodes.contains_key(&client) {
        let n_islands = spec.islands[client as usize] as usize;
        let streams =
            bind_client_streams(data, client as usize, n_islands.max(1), seq_width, spec.seed)?;
        nodes.insert(client, ClientNode::new(client as usize, streams));
    }
    Ok(nodes.get_mut(&client).unwrap())
}
