//! The deployment-plane LLM Node: connects to a `net::server` Aggregator,
//! pulls the task spec, and serves rounds until told to shut down
//! (paper §4.1 / Algorithm 1 L.12–27, over a real socket).
//!
//! Workers are **stateless**: every assignment carries the client's stream
//! cursors and KeepOpt moments, and every push returns them advanced. A
//! worker can therefore crash, be killed, or reconnect to a restarted
//! server without any local persistence — the Aggregator's checkpoint is
//! the only durable state. A crashed worker can even *rejoin the same
//! server* with its identity (`WorkerOpts::identity`) and reclaim its
//! slot and in-flight client leases. The local round itself is the *same
//! code* the in-process federation runs (`ClientNode::run_local_round`),
//! which is what makes a localhost fleet bit-identical to
//! `Federation::run`.
//!
//! The chaos plane injects here: `WorkerOpts::chaos` carries a
//! [`crate::chaos::WorkerChaos`] fault slice, and each round's fault
//! (crash / hang / slow / link flake) is acted out faithfully — see the
//! `chaos` module docs for the semantics each fault exercises.

use std::collections::BTreeMap;
use std::net::TcpStream;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::chaos::{self, Fault, WorkerChaos};
use crate::ckpt::ClientCkpt;
use crate::coordinator::federation::{bind_client_streams, build_data};
use crate::coordinator::ClientNode;
use crate::data::source::DataSource;
use crate::net::proto::{
    self, AssignState, Heartbeat, Join, Msg, TaskSpec, UpdatePush, PROTO_VERSION,
};
use crate::obs::{Event as ObsEvent, EventSink};
use crate::runtime::{ModelRuntime, Runtime};

/// Base sleep unit for the chaos `Slow` fault (multiplied by the fault's
/// factor, charged before every push).
const SLOW_UNIT_MS: u64 = 25;

/// Worker knobs (the test harness uses the fault hooks; the CLI only the
/// name/model fields).
#[derive(Clone, Default)]
pub struct WorkerOpts {
    /// Display name sent in the Join (logs only).
    pub name: String,
    /// Preloaded model runtime — the loopback harness shares one compiled
    /// model across the fleet; `None` loads `spec.model` from artifacts.
    pub model: Option<Arc<ModelRuntime>>,
    /// Test hook: drop the connection (simulating a crash) on receiving
    /// the assignment for this round, before replying.
    pub die_at_round: Option<u64>,
    /// Rejoin identity: `Some(slot)` asks the server to re-attach this
    /// connection to a previously held worker slot (and its in-flight
    /// leases) instead of admitting it fresh.
    pub identity: Option<u64>,
    /// Seeded per-round chaos faults (crash/hang/slow/flake) — see
    /// [`crate::chaos::Schedule::worker`].
    pub chaos: Option<WorkerChaos>,
    /// Optional observability sink: the worker's own view of the session
    /// (join, assignments received, updates pushed). The server's stream
    /// stays authoritative for cuts/rejoins/commits — in particular a
    /// rejoining worker logs a plain `WorkerJoin` here, because only the
    /// server can classify the join as a rejoin.
    pub obs: Option<EventSink>,
    pub verbose: bool,
}

/// What a worker did during one session.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerReport {
    pub worker_slot: u64,
    pub rounds_served: u64,
    pub updates_pushed: u64,
    /// Set when a crash hook (`die_at_round` or a chaos `Crash`) fired.
    pub aborted_at: Option<u64>,
    /// Set alongside `aborted_at` when the chaos schedule wants the
    /// crashed worker back: how long to wait before rejoining.
    pub rejoin_after_ms: Option<u64>,
    /// Rounds a chaos `Hang` made this worker sit out (acknowledged the
    /// assignment, pushed nothing).
    pub rounds_hung: u64,
    /// `UpdatePush` frames deliberately corrupted by a chaos `Flake`.
    pub frames_flaked: u64,
    /// On-wire size (length prefix + frame) of every `RoundAssign`
    /// received, in arrival order — the measurement behind the
    /// `AssignState::Ref` shrink tests.
    pub assign_bytes: Vec<u64>,
}

/// Connect to `addr`, join the federation, and serve rounds until the
/// server sends `Shutdown` (or a crash hook fires). Blocking.
pub fn run_worker(addr: &str, opts: WorkerOpts) -> Result<WorkerReport> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    proto::write_msg(
        &mut stream,
        &Msg::Join(Join {
            proto: PROTO_VERSION,
            name: opts.name.clone(),
            identity: opts.identity.map(|slot| slot + 1).unwrap_or(0),
        }),
        false,
    )?;
    let ack = match proto::read_msg(&mut stream)? {
        Msg::JoinAck(a) => a,
        Msg::Reject(r) => bail!("server rejected join: {}", r.reason),
        other => bail!("expected JoinAck, got {:?}", other.kind()),
    };
    ensure!(
        ack.proto == PROTO_VERSION,
        "server speaks photon-net v{}, this worker v{PROTO_VERSION} — upgrade",
        ack.proto
    );
    let spec = ack.spec;
    let model = match &opts.model {
        Some(m) => m.clone(),
        None => {
            let rt = Runtime::cpu()?;
            Arc::new(rt.load_model(&spec.model)?)
        }
    };
    ensure!(
        model.n_params() as u64 == spec.n_params,
        "model {} has {} params, server expects {} — artifact mismatch",
        spec.model,
        model.n_params(),
        spec.n_params
    );
    ensure!(
        spec.islands.len() == spec.n_clients as usize,
        "task spec carries {} island counts for {} clients",
        spec.islands.len(),
        spec.n_clients
    );

    // Build the identical data plane the Aggregator built: same corpus,
    // same partition, same per-client stream binding.
    let data = build_data(
        &spec.corpus,
        spec.n_clients as usize,
        spec.seed,
        model.manifest.config.vocab,
    );
    let seq_width = model.seq_width();
    let schedule = spec.schedule;
    let lr_at = move |t: u64| schedule.lr(t);

    let mut nodes: BTreeMap<u64, ClientNode> = BTreeMap::new();
    // States this worker provably holds: everything received in a Full
    // assignment plus every advanced state it pushed back. Pushing caches
    // optimistically — the push may yet be rejected or deadline-cut — but
    // that is safe because the server drops its generation claim for this
    // connection on any push it does not accept and for every cut client,
    // so it only ever sends `AssignState::Ref` for a generation this very
    // connection shipped or had accepted. A cache miss on a Ref is
    // therefore a protocol violation, not a recoverable condition.
    let mut cached: BTreeMap<u64, ClientCkpt> = BTreeMap::new();
    let mut report =
        WorkerReport { worker_slot: ack.worker_slot, ..WorkerReport::default() };
    let emit = |ev: ObsEvent| {
        if let Some(sink) = &opts.obs {
            sink.emit(ev);
        }
    };
    emit(ObsEvent::WorkerJoin { worker: ack.worker_slot, name: opts.name.clone() });
    if opts.verbose {
        println!(
            "[worker {}] joined session {:#x} as slot {} ({} clients, model {})",
            opts.name, ack.session, ack.worker_slot, spec.n_clients, spec.model
        );
    }

    loop {
        // Frame-then-decode (instead of `read_msg`) so the on-wire size of
        // each assignment can be recorded for the Ref-shrink measurement.
        let frame = proto::read_frame(&mut stream)?;
        match Msg::decode(&frame)? {
            Msg::RoundAssign(assign) => {
                report.assign_bytes.push(4 + frame.len() as u64);
                let fault = opts
                    .chaos
                    .as_ref()
                    .map(|c| c.fault(assign.round))
                    .unwrap_or(Fault::None);
                if opts.die_at_round == Some(assign.round) {
                    // Simulated crash: vanish mid-round without replying.
                    report.aborted_at = Some(assign.round);
                    return Ok(report);
                }
                if let Fault::Crash { rejoin_after_ms } = fault {
                    report.aborted_at = Some(assign.round);
                    report.rejoin_after_ms = rejoin_after_ms;
                    return Ok(report);
                }
                if assign.session != ack.session {
                    continue; // stale server incarnation
                }
                proto::write_msg(
                    &mut stream,
                    &Msg::Heartbeat(Heartbeat {
                        session: ack.session,
                        round: assign.round,
                    }),
                    false,
                )?;
                if fault == Fault::Hang {
                    // Sit the round out on a live connection: the server's
                    // deadline (or lease migration) resolves the silence.
                    report.rounds_hung += 1;
                    continue;
                }
                for (task_idx, task) in assign.tasks.iter().enumerate() {
                    emit(ObsEvent::LeaseGrant {
                        round: assign.round,
                        client: task.client,
                        worker: ack.worker_slot,
                    });
                    let node = node_for(
                        &mut nodes, &data, &spec, task.client, seq_width,
                    )?;
                    match &task.state {
                        AssignState::Full(s) => {
                            node.restore_state(s).with_context(|| {
                                format!("restoring client {}", task.client)
                            })?;
                            cached.insert(task.client, s.clone());
                        }
                        AssignState::Ref(_) => {
                            let Some(s) = cached.get(&task.client) else {
                                bail!(
                                    "assignment references client {} state this \
                                     worker does not hold",
                                    task.client
                                );
                            };
                            node.restore_state(s).with_context(|| {
                                format!("restoring client {} from cache", task.client)
                            })?;
                        }
                    }
                    let mut update = node
                        .run_local_round(
                            &model,
                            &assign.global,
                            task.steps,
                            assign.seq_base,
                            &lr_at,
                            spec.opt_state,
                        )
                        .with_context(|| {
                            format!("client {} round {}", task.client, assign.round)
                        })?;
                    // Apply the negotiated update codec (no-op body for the
                    // lossless codecs). Seeded per (round, client) from the
                    // task spec, so the encode is byte-identical to what
                    // the in-process federation computes — the parity
                    // invariant extends to lossy transport. Must run before
                    // `state()` so the error-feedback residual ships back.
                    let seed = crate::compress::transit_seed(
                        spec.seed,
                        assign.round,
                        task.client,
                    );
                    let transit = crate::compress::encode_transit(
                        &spec.codec,
                        &assign.global,
                        &update.params,
                        seed,
                        &mut node.residual,
                    )
                    .with_context(|| {
                        format!("encoding client {} update", task.client)
                    })?;
                    let state = node.state();
                    // The push makes the server record this generation as
                    // held here — keep the copy that backs a future Ref.
                    cached.insert(task.client, state.clone());
                    let body = match transit.body {
                        Some(b) => {
                            // Coded push: the dense params stay home.
                            update.params = Vec::new();
                            Some(b)
                        }
                        None => None,
                    };
                    if let Fault::Slow { factor } = fault {
                        std::thread::sleep(std::time::Duration::from_millis(
                            (factor * SLOW_UNIT_MS as f64) as u64,
                        ));
                    }
                    let msg = Msg::UpdatePush(UpdatePush {
                        session: ack.session,
                        round: assign.round,
                        // v5 staleness anchor: echo the dispatch epoch so
                        // the async server never trusts worker clocks.
                        lease_epoch: assign.lease_epoch,
                        update,
                        body,
                        state,
                    });
                    // The link-flake fault corrupts the victim task's frame
                    // *after* encoding, with a consistent length prefix —
                    // the server's stream framing survives, its link decode
                    // rejects the payload, and the affected client is cut
                    // like any straggler (never mis-decoded, never fatal).
                    let flake_this = matches!(
                        fault,
                        Fault::Flake { victim, .. }
                            if victim as usize % assign.tasks.len() == task_idx
                    );
                    if let (true, Fault::Flake { seed, .. }) = (flake_this, fault) {
                        let mut frame = msg.encode(spec.compress)?;
                        chaos::flake_frame(&mut frame, seed);
                        proto::write_frame(&mut stream, &frame)
                            .context("writing flaked frame")?;
                        report.frames_flaked += 1;
                    } else {
                        proto::write_msg(&mut stream, &msg, spec.compress)?;
                        report.updates_pushed += 1;
                        emit(ObsEvent::LeaseFold {
                            round: assign.round,
                            client: task.client,
                            worker: ack.worker_slot,
                        });
                    }
                }
                report.rounds_served += 1;
            }
            Msg::RoundCommit(c) => {
                if opts.verbose {
                    println!(
                        "[worker {}] round {} committed ({} participated, |g| {:.4})",
                        opts.name, c.round, c.participated, c.global_norm
                    );
                }
            }
            Msg::Shutdown => {
                emit(ObsEvent::Shutdown { rounds: report.rounds_served });
                return Ok(report);
            }
            Msg::Reject(r) => bail!("server rejected mid-session: {}", r.reason),
            other => bail!("unexpected {:?} from server", other.kind()),
        }
    }
}

/// Lazily build the node for `client` with the spec's island arity. The
/// initial binding state is irrelevant (every assignment restores the
/// authoritative cursors) but the *structure* — island and bucket arity —
/// must match the Aggregator's, which `bind_client_streams` guarantees.
fn node_for<'a>(
    nodes: &'a mut BTreeMap<u64, ClientNode>,
    data: &DataSource,
    spec: &TaskSpec,
    client: u64,
    seq_width: usize,
) -> Result<&'a mut ClientNode> {
    ensure!(
        (client as usize) < spec.n_clients as usize,
        "assignment names client {client}, spec has {} clients",
        spec.n_clients
    );
    if !nodes.contains_key(&client) {
        let n_islands = spec.islands[client as usize] as usize;
        let streams =
            bind_client_streams(data, client as usize, n_islands.max(1), seq_width, spec.seed)?;
        nodes.insert(client, ClientNode::new(client as usize, streams));
    }
    nodes
        .get_mut(&client)
        .ok_or_else(|| anyhow::anyhow!("client node {client} vanished after insert"))
}
