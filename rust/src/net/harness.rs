//! Deterministic in-process loopback fleet: one `net::server` Aggregator
//! plus K `net::worker` threads over `127.0.0.1` TCP, sharing a single
//! compiled model runtime. This is the test/experiment entry point for the
//! deployment plane — `photon exp distributed`, `photon exp chaos`, and
//! `tests/integration_net.rs` / `tests/integration_chaos.rs` drive it to
//! prove bit-exact parity with the in-process `Federation::run`.
//!
//! With a [`chaos::Schedule`] injected, each worker thread acts out its
//! per-round faults (crash, hang, slow, link flake) and — when the
//! schedule says so — **rejoins** the server after a delay with its
//! identity, reclaiming its slot and in-flight leases. The realized
//! outcome (cuts, migrations, rejoins) comes back as
//! [`FleetReport::trace`], replayable bit-exactly with
//! `Federation::run_trace`.
//!
//! Thread collection runs under a watchdog ([`FleetOpts::watchdog_secs`]):
//! a wedged worker or server fails the run with a diagnosis naming the
//! stuck threads instead of hanging the whole test suite on a `join`.
//! (The stuck threads are left detached; the server's shutdown path
//! unblocks their sockets soon after, and test processes exit anyway.)

// Wall-clock reads here drive process liveness and kill schedules —
// allowlisted; see docs/ANALYSIS.md (nondet-time).
#![allow(clippy::disallowed_methods)]

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::chaos;
use crate::config::ExperimentConfig;
use crate::coordinator::Federation;
use crate::metrics::RoundRecord;
use crate::net::server::{ServeOpts, Server};
use crate::net::subagg::{run_subagg, SubaggOpts, SubaggReport};
use crate::net::worker::{run_worker, WorkerOpts, WorkerReport};
use crate::obs::{self, Event as ObsEvent, EventSink};
use crate::runtime::ModelRuntime;

/// Loopback-fleet knobs.
#[derive(Clone)]
pub struct FleetOpts {
    /// Worker threads to spawn (the server waits for all of them; in tree
    /// mode they are split round-robin across the sub-aggregators).
    pub workers: usize,
    /// Sub-aggregator threads (tree mode). Must be > 0 exactly when
    /// `cfg.tiers > 1`; each leases one slice of every sampled cohort.
    /// Workers connect to the sub-aggregators, never to the root. The
    /// downstream straggler deadline is half of `deadline_secs`, so a
    /// sub-aggregator always cuts and pushes before the root's own timer
    /// would cut the whole slice.
    pub subaggs: usize,
    /// Resident-byte budget for the root's client-state cache
    /// ([`crate::ckpt::StateStore`]); colder states spill to disk.
    pub state_budget: Option<u64>,
    /// Per-round straggler deadline (None = disconnects only).
    pub deadline_secs: Option<f64>,
    /// Deflate model payloads on the wire.
    pub compress: bool,
    /// Fault hooks: worker index → round at which it "crashes"
    /// (disconnects mid-round without replying). The chaos schedule is
    /// the richer generalization; this stays for targeted drills.
    pub die_at_round: BTreeMap<usize, u64>,
    /// Seeded per-(worker, round) fault plan: crash (with rejoin), hang,
    /// slow-down, link flake. Hang/flake cells require `deadline_secs`.
    pub chaos: Option<chaos::Schedule>,
    /// Opt-in mid-round client-lease migration (requires a deadline).
    pub migrate: bool,
    /// Buffered **asynchronous** aggregation `(k, gamma)`: the server
    /// folds the first `k` arrivals of each epoch with staleness-
    /// discounted weights (`w·γ^staleness`) and re-leases finished
    /// workers immediately — no round barrier. Flat fleets only
    /// (`subaggs == 0`); the realized run comes back as
    /// [`FleetReport::async_trace`], replayable bit-exactly with
    /// `Federation::run_async_trace`.
    pub async_agg: Option<(usize, f64)>,
    /// Checkpoint directory for the server federation.
    pub ckpt_dir: Option<PathBuf>,
    /// Resume the server from the latest checkpoint in `ckpt_dir`.
    pub resume: bool,
    /// Watchdog on collecting the worker/server threads: `Some(s)` fails
    /// the run with a diagnosis after `s` seconds instead of wedging the
    /// suite on a hung thread; `None` waits forever.
    pub watchdog_secs: Option<f64>,
    /// Write the server's structured JSONL event stream here (`obs`
    /// plane); `None` disables emission. Watchdog diagnoses land in the
    /// same log as `Stall` events, so a wedged run leaves evidence.
    pub obs_log: Option<PathBuf>,
}

impl Default for FleetOpts {
    fn default() -> FleetOpts {
        FleetOpts {
            workers: 1,
            subaggs: 0,
            state_budget: None,
            deadline_secs: None,
            compress: true,
            die_at_round: BTreeMap::new(),
            chaos: None,
            migrate: false,
            async_agg: None,
            ckpt_dir: None,
            resume: false,
            watchdog_secs: Some(600.0),
            obs_log: None,
        }
    }
}

/// Everything a loopback run produces.
pub struct FleetReport {
    /// The server's complete round-record log (includes pre-resume rounds
    /// only if the log was rebuilt — on a resume it holds the rounds this
    /// incarnation executed).
    pub records: Vec<RoundRecord>,
    /// Final global model (bit-comparable to `Federation::run`'s).
    pub global: Vec<f32>,
    /// Realized deadline/disconnect cuts per round.
    pub cuts: Vec<(usize, Vec<usize>)>,
    /// The full realized chaos trace (cuts + migrations + rejoins),
    /// replayable bit-exactly with `Federation::run_trace`.
    pub trace: chaos::Trace,
    /// The realized async ledger (grants, folds, cuts) when the fleet ran
    /// with [`FleetOpts::async_agg`]; replayable bit-exactly with
    /// `Federation::run_async_trace`. `None` for sync fleets.
    pub async_trace: Option<chaos::AsyncTrace>,
    /// Per logical worker, merged across its crash/rejoin sessions.
    pub workers: Vec<WorkerReport>,
    /// Per sub-aggregator (empty for a flat fleet).
    pub subaggs: Vec<SubaggReport>,
    /// Errors from worker or sub-aggregator threads (a crashed-by-hook
    /// worker is *not* an error; it reports `aborted_at`).
    pub worker_errors: Vec<String>,
    /// Root `StateStore` statistics: states spilled to disk and loaded
    /// back over the run (nonzero proves the budget actually bit).
    pub store_spills: u64,
    pub store_loads: u64,
    /// High-water mark of encoded client-state bytes the root's store
    /// held resident — 0 with no `state_budget` configured (the store
    /// runs generation-only and the federation's own states serve
    /// assigns). Read after shutdown: the peak survives spill cleanup.
    pub store_resident_peak: u64,
}

/// One logical worker's thread: serve sessions, crashing and rejoining as
/// the chaos schedule dictates, until the server shuts the fleet down.
fn worker_thread(
    addr: String,
    index: usize,
    model: Arc<ModelRuntime>,
    die_at_round: Option<u64>,
    mut chaos_w: Option<chaos::WorkerChaos>,
) -> Result<WorkerReport> {
    let mut merged = WorkerReport::default();
    let mut identity: Option<u64> = None;
    let mut sessions = 0u64;
    let mut retries = 0u32;
    loop {
        let wopts = WorkerOpts {
            name: format!("loopback-{index}"),
            model: Some(model.clone()),
            die_at_round: if sessions == 0 { die_at_round } else { None },
            identity,
            chaos: chaos_w.clone(),
            obs: None,
            verbose: false,
        };
        match run_worker(&addr, wopts) {
            Ok(r) => {
                merged.worker_slot = r.worker_slot;
                merged.rounds_served += r.rounds_served;
                merged.updates_pushed += r.updates_pushed;
                merged.rounds_hung += r.rounds_hung;
                merged.frames_flaked += r.frames_flaked;
                merged.assign_bytes.extend(r.assign_bytes);
                if r.aborted_at.is_some() {
                    // Remember the last crash even after clean rejoined
                    // sessions (diagnostics only).
                    merged.aborted_at = r.aborted_at;
                    merged.rejoin_after_ms = r.rejoin_after_ms;
                }
                match (r.aborted_at, r.rejoin_after_ms) {
                    (Some(round), Some(delay_ms)) => {
                        // Crash with a rejoin: come back with our identity
                        // after the delay. Consume the crash cell first so
                        // a re-dispatch of the same round does not crash
                        // the rejoined session in a loop.
                        if let Some(c) = chaos_w.as_mut() {
                            c.consume(round);
                        }
                        identity = Some(r.worker_slot);
                        std::thread::sleep(Duration::from_millis(delay_ms));
                        sessions += 1;
                        retries = 0;
                    }
                    _ => return Ok(merged),
                }
            }
            // A rejoin can race the server processing our disconnect (the
            // slot still looks alive ⇒ "not reclaimable"); back off and
            // retry a few times before giving up.
            Err(e)
                if sessions > 0
                    && retries < 3
                    && format!("{e:#}").contains("reclaimable") =>
            {
                retries += 1;
                std::thread::sleep(Duration::from_millis(50));
            }
            // A rejoin that raced the end of the run (server already shut
            // down, socket refused, or the slot re-admission kept being
            // refused) is a clean exit for an elastic worker, not a
            // failure.
            Err(_) if sessions > 0 => return Ok(merged),
            Err(e) => return Err(e),
        }
    }
}

/// Run a whole federation over localhost TCP with `opts.workers` workers.
/// Deterministic given (cfg, opts): the record stream and final global
/// model match the in-process `Federation::run` bit-for-bit when no cuts
/// occur, and match `Federation::run_trace` replayed with
/// [`FleetReport::trace`] when chaos strikes.
pub fn run_loopback(
    cfg: ExperimentConfig,
    model: Arc<ModelRuntime>,
    opts: FleetOpts,
) -> Result<FleetReport> {
    anyhow::ensure!(
        (opts.subaggs > 0) == (cfg.tiers > 1),
        "sub-aggregators ({}) and cfg.tiers ({}) must agree: a tiered \
         federation runs through sub-aggregators, a flat one never does",
        opts.subaggs,
        cfg.tiers
    );
    // Every tree round needs one live sub-aggregator per tier group
    // (`tier_slices` makes min(tiers, K) groups); too few would leave the
    // root waiting out its full join timeout every round before bailing —
    // a pure config error surfaced as a slow hang. Fail fast instead.
    let max_groups = cfg.tiers.min(cfg.clients_per_round);
    anyhow::ensure!(
        opts.subaggs == 0 || opts.subaggs >= max_groups,
        "tree fleet needs one sub-aggregator per tier group: cfg.tiers = {} \
         with clients_per_round = {} makes up to {} group(s) per round, only \
         {} sub-aggregator(s) configured",
        cfg.tiers,
        cfg.clients_per_round,
        max_groups,
        opts.subaggs
    );
    anyhow::ensure!(
        opts.subaggs == 0 || opts.workers >= opts.subaggs,
        "tree fleet needs at least one worker per sub-aggregator ({} workers, \
         {} sub-aggregators)",
        opts.workers,
        opts.subaggs
    );
    anyhow::ensure!(
        opts.async_agg.is_none() || opts.subaggs == 0,
        "async aggregation is flat-only: it has no round barrier for a tree \
         to slice"
    );
    anyhow::ensure!(
        opts.async_agg.is_none() || !opts.resume,
        "async aggregation does not support checkpoint resume: the replay \
         trace must start from epoch 0"
    );
    if let Some(schedule) = &opts.chaos {
        anyhow::ensure!(
            schedule.workers >= opts.workers,
            "chaos schedule covers {} workers, fleet has {}",
            schedule.workers,
            opts.workers
        );
        anyhow::ensure!(
            opts.deadline_secs.is_some() || !schedule.needs_deadline(),
            "this chaos schedule hangs/flakes workers — set deadline_secs so \
             the silent leases are cut instead of wedging the round"
        );
    }
    let mut fed = Federation::with_model(cfg, model.clone())?;
    if let Some(dir) = &opts.ckpt_dir {
        fed.ckpt_dir = Some(dir.clone());
        if opts.resume {
            fed.try_resume_from(dir)?;
        }
    }
    // The harness keeps a handle on the sink so watchdog diagnoses reach
    // the same log the server writes its fleet events to.
    let obs_sink: Option<EventSink> = match &opts.obs_log {
        Some(path) => Some(EventSink::to_file(path)?),
        None => None,
    };
    fed.obs = obs_sink.clone();
    let tree = opts.subaggs > 0;
    let serve = ServeOpts {
        bind: "127.0.0.1:0".into(),
        // In tree mode the root admits sub-aggregators, not workers.
        min_workers: if tree { opts.subaggs } else { opts.workers },
        deadline_secs: opts.deadline_secs,
        migrate: opts.migrate,
        compress: opts.compress,
        state_budget: opts.state_budget,
        async_agg: opts.async_agg,
        ..ServeOpts::default()
    };
    let mut server = Server::with_federation(fed, serve)?;
    let addr = server.local_addr().to_string();

    // Results come back over channels so collection can time out with a
    // diagnosis — a `JoinHandle::join` on a wedged thread would hang the
    // whole suite (the ISSUE 5 watchdog satellite). Panics are caught and
    // reported as results, never left to vanish with the sender.
    let (stx, srx) = mpsc::channel();
    std::thread::spawn(move || {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let result = server.run();
            (server, result)
        }))
        .map_err(|_| "server thread panicked".to_string());
        let _ = stx.send(outcome);
    });
    // Tree mode: spawn the sub-aggregators first and collect their bound
    // downstream addresses; workers connect to those, never to the root.
    let (sgtx, sgrx) = mpsc::channel();
    let mut sub_addrs: Vec<String> = Vec::new();
    for i in 0..opts.subaggs {
        let per_sub =
            opts.workers / opts.subaggs + usize::from(i < opts.workers % opts.subaggs);
        let sopts = SubaggOpts {
            name: format!("subagg-{i}"),
            bind: "127.0.0.1:0".into(),
            min_workers: per_sub.max(1),
            // Cut downstream stragglers well before the root's own timer
            // would cut this sub-aggregator's whole slice.
            deadline_secs: opts.deadline_secs.map(|s| s / 2.0),
            ..SubaggOpts::default()
        };
        let root = addr.clone();
        let (atx, arx) = mpsc::channel();
        let sgtx = sgtx.clone();
        std::thread::spawn(move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_subagg(&root, sopts, Some(atx))
            }))
            .unwrap_or_else(|_| {
                Err(anyhow::anyhow!("sub-aggregator thread panicked"))
            });
            let _ = sgtx.send((i, result));
        });
        let sub_addr = arx
            .recv_timeout(Duration::from_secs(30))
            .with_context(|| format!("sub-aggregator {i} never bound its listener"))?;
        sub_addrs.push(sub_addr.to_string());
    }
    drop(sgtx);

    let (wtx, wrx) = mpsc::channel();
    for i in 0..opts.workers {
        let addr = if sub_addrs.is_empty() {
            addr.clone()
        } else {
            sub_addrs[i % sub_addrs.len()].clone()
        };
        let model = model.clone();
        let die = opts.die_at_round.get(&i).copied();
        let chaos_w = opts.chaos.as_ref().map(|s| s.worker(i));
        let wtx = wtx.clone();
        std::thread::spawn(move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                worker_thread(addr, i, model, die, chaos_w)
            }))
            .unwrap_or_else(|_| Err(anyhow::anyhow!("worker thread panicked")));
            let _ = wtx.send((i, result));
        });
    }
    drop(wtx);

    let give_up = opts
        .watchdog_secs
        .map(|s| Instant::now() + Duration::from_secs_f64(s));
    let mut workers: Vec<Option<WorkerReport>> = (0..opts.workers).map(|_| None).collect();
    let mut worker_errors = Vec::new();
    let mut collected = 0usize;
    while collected < opts.workers {
        match recv_until(&wrx, give_up) {
            Some((i, Ok(report))) => {
                workers[i] = Some(report);
                collected += 1;
            }
            Some((i, Err(e))) => {
                worker_errors.push(format!("worker {i}: {e:#}"));
                workers[i] = Some(WorkerReport::default());
                collected += 1;
            }
            None => {
                let stuck: Vec<usize> =
                    (0..opts.workers).filter(|&i| workers[i].is_none()).collect();
                let waited = opts.watchdog_secs.unwrap_or(0.0);
                obs::timing("harness", "watchdog", waited);
                if let Some(sink) = &obs_sink {
                    sink.emit(ObsEvent::Stall {
                        round: None,
                        waited_us: (waited * 1e6) as u64,
                        detail: format!("worker thread(s) {stuck:?} never finished"),
                    });
                }
                bail!(
                    "loopback watchdog ({}) fired: worker thread(s) {stuck:?} never \
                     finished — likely a wedged round (no deadline set?) or a \
                     deadlocked join; the server thread is abandoned",
                    watchdog_label(opts.watchdog_secs),
                );
            }
        }
    }
    let mut subagg_reports: Vec<Option<SubaggReport>> =
        (0..opts.subaggs).map(|_| None).collect();
    let mut collected_subs = 0usize;
    while collected_subs < opts.subaggs {
        match recv_until(&sgrx, give_up) {
            Some((i, Ok(report))) => {
                subagg_reports[i] = Some(report);
                collected_subs += 1;
            }
            Some((i, Err(e))) => {
                worker_errors.push(format!("subagg {i}: {e:#}"));
                subagg_reports[i] = Some(SubaggReport::default());
                collected_subs += 1;
            }
            None => {
                let stuck: Vec<usize> = (0..opts.subaggs)
                    .filter(|&i| subagg_reports[i].is_none())
                    .collect();
                let waited = opts.watchdog_secs.unwrap_or(0.0);
                obs::timing("harness", "watchdog", waited);
                if let Some(sink) = &obs_sink {
                    sink.emit(ObsEvent::Stall {
                        round: None,
                        waited_us: (waited * 1e6) as u64,
                        detail: format!(
                            "sub-aggregator thread(s) {stuck:?} never finished"
                        ),
                    });
                }
                bail!(
                    "loopback watchdog ({}) fired: sub-aggregator thread(s) \
                     {stuck:?} never finished",
                    watchdog_label(opts.watchdog_secs),
                );
            }
        }
    }
    let (server, result) = match recv_until(&srx, give_up) {
        Some(Ok(pair)) => pair,
        Some(Err(panic_msg)) => bail!("server run failed: {panic_msg}"),
        None => {
            let waited = opts.watchdog_secs.unwrap_or(0.0);
            obs::timing("harness", "watchdog", waited);
            if let Some(sink) = &obs_sink {
                sink.emit(ObsEvent::Stall {
                    round: None,
                    waited_us: (waited * 1e6) as u64,
                    detail: "server thread never returned".to_string(),
                });
            }
            bail!(
                "loopback watchdog ({}) fired: every worker finished but the server \
                 thread never returned — wedged round loop or acceptor deadlock",
                watchdog_label(opts.watchdog_secs),
            )
        }
    };
    let records = result.context("server run failed")?;
    Ok(FleetReport {
        records,
        global: server.federation().global.clone(),
        cuts: server.cuts.clone(),
        trace: server.trace(),
        async_trace: server.async_trace(),
        workers: workers.into_iter().map(|w| w.unwrap_or_default()).collect(),
        subaggs: subagg_reports.into_iter().map(|s| s.unwrap_or_default()).collect(),
        worker_errors,
        store_spills: server.state_store().spill_count(),
        store_loads: server.state_store().load_count(),
        store_resident_peak: server.state_store().resident_peak(),
    })
}

fn watchdog_label(secs: Option<f64>) -> String {
    secs.map(|s| format!("{s}s")).unwrap_or_else(|| "no timeout".into())
}

/// Receive one value, bounded by the optional watchdog instant. `None`
/// means the watchdog fired (or every sender vanished without a value —
/// equally a diagnosis-worthy wedge).
fn recv_until<T>(rx: &mpsc::Receiver<T>, give_up: Option<Instant>) -> Option<T> {
    match give_up {
        None => rx.recv().ok(),
        Some(at) => {
            let now = Instant::now();
            if now >= at {
                return None;
            }
            rx.recv_timeout(at - now).ok()
        }
    }
}
