//! Deterministic in-process loopback fleet: one `net::server` Aggregator
//! plus K `net::worker` threads over `127.0.0.1` TCP, sharing a single
//! compiled model runtime. This is the test/experiment entry point for the
//! deployment plane — `photon exp distributed` and
//! `tests/integration_net.rs` drive it to prove bit-exact parity with the
//! in-process `Federation::run`.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::Federation;
use crate::metrics::RoundRecord;
use crate::net::server::{ServeOpts, Server};
use crate::net::worker::{run_worker, WorkerOpts, WorkerReport};
use crate::runtime::ModelRuntime;

/// Loopback-fleet knobs.
#[derive(Clone, Default)]
pub struct FleetOpts {
    /// Worker threads to spawn (the server waits for all of them).
    pub workers: usize,
    /// Per-round straggler deadline (None = disconnects only).
    pub deadline_secs: Option<f64>,
    /// Deflate model payloads on the wire.
    pub compress: bool,
    /// Fault hooks: worker index → round at which it "crashes"
    /// (disconnects mid-round without replying).
    pub die_at_round: HashMap<usize, u64>,
    /// Checkpoint directory for the server federation.
    pub ckpt_dir: Option<PathBuf>,
    /// Resume the server from the latest checkpoint in `ckpt_dir`.
    pub resume: bool,
}

/// Everything a loopback run produces.
pub struct FleetReport {
    /// The server's complete round-record log (includes pre-resume rounds
    /// only if the log was rebuilt — on a resume it holds the rounds this
    /// incarnation executed).
    pub records: Vec<RoundRecord>,
    /// Final global model (bit-comparable to `Federation::run`'s).
    pub global: Vec<f32>,
    /// Realized deadline/disconnect cuts per round.
    pub cuts: Vec<(usize, Vec<usize>)>,
    pub workers: Vec<WorkerReport>,
    /// Errors from worker threads (a crashed-by-hook worker is *not* an
    /// error; it reports `aborted_at`).
    pub worker_errors: Vec<String>,
}

/// Run a whole federation over localhost TCP with `opts.workers` workers.
/// Deterministic given (cfg, opts): the record stream and final global
/// model match the in-process `Federation::run` bit-for-bit when no cuts
/// occur, and match `Federation::run_round_cut` replayed with
/// `FleetReport::cuts` when they do.
pub fn run_loopback(
    cfg: ExperimentConfig,
    model: Arc<ModelRuntime>,
    opts: FleetOpts,
) -> Result<FleetReport> {
    let mut fed = Federation::with_model(cfg, model.clone())?;
    if let Some(dir) = &opts.ckpt_dir {
        fed.ckpt_dir = Some(dir.clone());
        if opts.resume {
            fed.try_resume_from(dir)?;
        }
    }
    let serve = ServeOpts {
        bind: "127.0.0.1:0".into(),
        min_workers: opts.workers,
        deadline_secs: opts.deadline_secs,
        compress: opts.compress,
        ..ServeOpts::default()
    };
    let mut server = Server::with_federation(fed, serve)?;
    let addr = server.local_addr().to_string();

    let server_handle = std::thread::spawn(move || {
        let result = server.run();
        (server, result)
    });
    let worker_handles: Vec<_> = (0..opts.workers)
        .map(|i| {
            let addr = addr.clone();
            let wopts = WorkerOpts {
                name: format!("loopback-{i}"),
                model: Some(model.clone()),
                die_at_round: opts.die_at_round.get(&i).copied(),
                verbose: false,
            };
            std::thread::spawn(move || run_worker(&addr, wopts))
        })
        .collect();

    let mut workers = Vec::new();
    let mut worker_errors = Vec::new();
    for (i, h) in worker_handles.into_iter().enumerate() {
        match h.join() {
            Ok(Ok(report)) => workers.push(report),
            Ok(Err(e)) => worker_errors.push(format!("worker {i}: {e:#}")),
            Err(_) => worker_errors.push(format!("worker {i}: panicked")),
        }
    }
    let (server, result) = server_handle
        .join()
        .map_err(|_| anyhow::anyhow!("server thread panicked"))?;
    let records = result.context("server run failed")?;
    Ok(FleetReport {
        records,
        global: server.federation().global.clone(),
        cuts: server.cuts.clone(),
        workers,
        worker_errors,
    })
}
