//! Nonblocking accept/read plane for the Aggregator and sub-aggregators:
//! ONE polling thread owns the listener and every admitted socket,
//! replacing the thread-per-connection reader fleet. At paper scale the
//! root admits thousands of peers; a reader thread per socket is exactly
//! the resource wall the polling loop removes.
//!
//! Design (std::net only — no epoll/kqueue bindings, no new deps):
//!
//! * the listener and every accepted stream run with
//!   `set_nonblocking(true)`;
//! * each iteration drains `accept()` to `WouldBlock`, then sweeps a
//!   ready-list of connections, reading whatever bytes each socket has
//!   into a per-connection buffer and slicing complete `u32`
//!   length-prefixed frames out of it;
//! * a sweep that moves no bytes sleeps ~1ms before the next one, so an
//!   idle fleet costs a handful of wakeups per second, not a spin.
//!
//! Frame semantics match the blocking reader it replaces
//! (`proto::read_frame` / `Msg::decode`): the first decodable frame on a
//! connection must be `Join` or `SubJoin` (anything else silently drops
//! the peer), a framed-but-undecodable payload is reported as
//! [`Event::Malformed`] with the stream kept alive, and only an IO error,
//! EOF, or an implausible length prefix (stream framing lost) tears the
//! connection down with [`Event::Gone`].
//!
//! Because `set_nonblocking` applies to the whole socket, the write half
//! handed out in [`Event::Joined`] is nonblocking too — writers must go
//! through [`NbWriter`], which retries `WouldBlock` against a deadline
//! (the moral equivalent of the old `set_write_timeout`).

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::net::proto::{self, Msg};

/// What the polling thread reports to the service loop. Mirrors the shape
/// of the old per-thread reader events, plus the v4 `sub` flag so the
/// server can route sub-aggregator admissions to the tree plane.
pub enum Event {
    /// First frame decoded as `Join` (`sub = false`) or `SubJoin`
    /// (`sub = true`). `stream` is a nonblocking write half — wrap it in
    /// [`NbWriter`] before use.
    Joined { conn: usize, stream: TcpStream, join: proto::Join, sub: bool },
    Frame { conn: usize, msg: Msg },
    /// Framing survived (length prefix intact) but link decode failed —
    /// a flaked payload. The stream itself is still good.
    Malformed { conn: usize },
    Gone { conn: usize },
}

/// One polled connection: its socket, its incremental read buffer, and
/// whether its Join/SubJoin admission frame has been seen.
struct Conn {
    id: usize,
    stream: TcpStream,
    buf: Vec<u8>,
    joined: bool,
}

/// Sweep outcome for one connection.
enum Sweep {
    /// Bytes moved (or at least one frame completed) this pass.
    Progress,
    Idle,
    /// EOF, IO error, or lost framing: drop the connection.
    Dead,
}

const IDLE_SLEEP: Duration = Duration::from_millis(1);
const READ_CHUNK: usize = 64 * 1024;

/// Start the polling thread: nonblocking accept + read over every
/// connection, events delivered on `tx`. The thread exits when `stop` is
/// set (checked every sweep, so within ~1ms of the store) or when the
/// receiver hangs up.
pub fn spawn_poller(
    listener: TcpListener,
    tx: Sender<Event>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    listener
        .set_nonblocking(true)
        .context("setting listener nonblocking")?;
    std::thread::spawn(move || poll_loop(listener, tx, stop));
    Ok(())
}

fn poll_loop(listener: TcpListener, tx: Sender<Event>, stop: Arc<AtomicBool>) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut next_id = 0usize;
    while !stop.load(Ordering::Acquire) {
        let mut progressed = false;
        // Drain the accept queue.
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    conns.push(Conn {
                        id: next_id,
                        stream,
                        buf: Vec::new(),
                        joined: false,
                    });
                    next_id += 1;
                    progressed = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        // Ready-list sweep: every connection with readable bytes makes
        // progress this pass; the rest report Idle instantly.
        let mut i = 0;
        while i < conns.len() {
            match sweep_conn(&mut conns[i], &tx) {
                Sweep::Progress => {
                    progressed = true;
                    i += 1;
                }
                Sweep::Idle => i += 1,
                Sweep::Dead => {
                    let c = conns.swap_remove(i);
                    if c.joined && tx.send(Event::Gone { conn: c.id }).is_err() {
                        return;
                    }
                    progressed = true;
                }
            }
        }
        if !progressed {
            std::thread::sleep(IDLE_SLEEP);
        }
    }
}

/// Read whatever `c`'s socket has, then emit every complete frame in its
/// buffer.
fn sweep_conn(c: &mut Conn, tx: &Sender<Event>) -> Sweep {
    let mut chunk = [0u8; READ_CHUNK];
    let mut moved = false;
    loop {
        match c.stream.read(&mut chunk) {
            Ok(0) => return Sweep::Dead,
            Ok(n) => {
                c.buf.extend_from_slice(&chunk[..n]);
                moved = true;
                if n < chunk.len() {
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return Sweep::Dead,
        }
    }
    if !moved {
        return Sweep::Idle;
    }
    // Slice complete length-prefixed frames out of the buffer.
    loop {
        if c.buf.len() < 4 {
            break;
        }
        let len = u32::from_le_bytes([c.buf[0], c.buf[1], c.buf[2], c.buf[3]]) as usize;
        if !(crate::link::HEADER_BYTES..=proto::MAX_FRAME_BYTES).contains(&len) {
            // Stream framing lost — same fate as an IO error.
            return Sweep::Dead;
        }
        if c.buf.len() < 4 + len {
            break;
        }
        // Split the frame off the front without re-sizing by the wire
        // length: `split_off` is bounded by what actually arrived.
        let mut rest = c.buf.split_off(4 + len);
        std::mem::swap(&mut c.buf, &mut rest);
        let framed = rest;
        let event = match Msg::decode(&framed[4..]) {
            Ok(msg) if !c.joined => {
                // Admission: the first frame must be Join or SubJoin.
                let (join, sub) = match msg {
                    Msg::Join(j) => (j, false),
                    Msg::SubJoin(j) => (j, true),
                    _ => return Sweep::Dead,
                };
                c.joined = true;
                let stream = match c.stream.try_clone() {
                    Ok(s) => s,
                    Err(_) => return Sweep::Dead,
                };
                Event::Joined { conn: c.id, stream, join, sub }
            }
            Ok(msg) => Event::Frame { conn: c.id, msg },
            Err(_) if !c.joined => return Sweep::Dead,
            Err(_) => Event::Malformed { conn: c.id },
        };
        if tx.send(event).is_err() {
            return Sweep::Dead;
        }
    }
    Sweep::Progress
}

/// Blocking-writer adapter over a nonblocking socket: retries
/// `WouldBlock` with a short sleep until the per-call deadline expires.
/// Every write path that used to rely on `set_write_timeout` (the server,
/// the sub-aggregator) goes through this instead.
pub struct NbWriter {
    stream: TcpStream,
    timeout: Duration,
}

impl NbWriter {
    pub fn new(stream: TcpStream, timeout_secs: f64) -> NbWriter {
        NbWriter { stream, timeout: Duration::from_secs_f64(timeout_secs.max(0.001)) }
    }

    /// The wrapped socket (e.g. for `peer_addr` diagnostics).
    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }
}

impl Write for NbWriter {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let deadline = Instant::now() + self.timeout;
        loop {
            match self.stream.write(buf) {
                Ok(n) => return Ok(n),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "write stalled past the io timeout",
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.stream.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::proto::{Heartbeat, Join, PROTO_VERSION};
    use std::sync::mpsc;

    fn join_msg(name: &str, sub: bool) -> Msg {
        let j = Join { proto: PROTO_VERSION, name: name.into(), identity: 0 };
        if sub {
            Msg::SubJoin(j)
        } else {
            Msg::Join(j)
        }
    }

    fn start() -> (std::net::SocketAddr, mpsc::Receiver<Event>, Arc<AtomicBool>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (tx, rx) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        spawn_poller(listener, tx, stop.clone()).unwrap();
        (addr, rx, stop)
    }

    #[test]
    fn polls_join_frames_and_disconnects() {
        let (addr, rx, stop) = start();
        let mut s = TcpStream::connect(addr).unwrap();
        proto::write_msg(&mut s, &join_msg("w0", false), false).unwrap();
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Event::Joined { join, sub, .. } => {
                assert_eq!(join.name, "w0");
                assert!(!sub);
            }
            _ => panic!("expected Joined"),
        }
        proto::write_msg(&mut s, &Msg::Heartbeat(Heartbeat { session: 1, round: 2 }), false)
            .unwrap();
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Event::Frame { msg: Msg::Heartbeat(h), .. } => assert_eq!(h.round, 2),
            _ => panic!("expected Heartbeat frame"),
        }
        drop(s);
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Event::Gone { .. } => {}
            _ => panic!("expected Gone"),
        }
        stop.store(true, Ordering::Release);
    }

    #[test]
    fn sub_join_is_flagged() {
        let (addr, rx, stop) = start();
        let mut s = TcpStream::connect(addr).unwrap();
        proto::write_msg(&mut s, &join_msg("sub0", true), false).unwrap();
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Event::Joined { join, sub, .. } => {
                assert_eq!(join.name, "sub0");
                assert!(sub, "SubJoin must surface with sub = true");
            }
            _ => panic!("expected Joined"),
        }
        stop.store(true, Ordering::Release);
        drop(s);
    }

    #[test]
    fn fragmented_writes_reassemble() {
        // A frame delivered one byte at a time must still come out whole —
        // the incremental parser may never split or duplicate it.
        let (addr, rx, stop) = start();
        let mut s = TcpStream::connect(addr).unwrap();
        proto::write_msg(&mut s, &join_msg("w0", false), false).unwrap();
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Event::Joined { .. }
        ));
        let frame = Msg::Heartbeat(Heartbeat { session: 9, round: 4 })
            .encode(false)
            .unwrap();
        let mut wire = (frame.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&frame);
        for b in wire {
            s.write_all(&[b]).unwrap();
            s.flush().unwrap();
        }
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Event::Frame { msg: Msg::Heartbeat(h), .. } => {
                assert_eq!(h.session, 9);
                assert_eq!(h.round, 4);
            }
            _ => panic!("expected reassembled Heartbeat"),
        }
        stop.store(true, Ordering::Release);
        drop(s);
    }

    #[test]
    fn malformed_frame_reported_stream_survives() {
        let (addr, rx, stop) = start();
        let mut s = TcpStream::connect(addr).unwrap();
        proto::write_msg(&mut s, &join_msg("w0", false), false).unwrap();
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Event::Joined { .. }
        ));
        // A correctly framed garbage payload: link decode fails, framing
        // survives, and the next real frame still gets through.
        let garbage = vec![0xAAu8; crate::link::HEADER_BYTES + 8];
        proto::write_frame(&mut s, &garbage).unwrap();
        proto::write_msg(&mut s, &Msg::Heartbeat(Heartbeat { session: 1, round: 7 }), false)
            .unwrap();
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Event::Malformed { .. } => {}
            _ => panic!("expected Malformed"),
        }
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Event::Frame { msg: Msg::Heartbeat(h), .. } => assert_eq!(h.round, 7),
            _ => panic!("stream must survive a flaked frame"),
        }
        stop.store(true, Ordering::Release);
        drop(s);
    }

    #[test]
    fn implausible_length_prefix_drops_connection() {
        let (addr, rx, stop) = start();
        let mut s = TcpStream::connect(addr).unwrap();
        proto::write_msg(&mut s, &join_msg("w0", false), false).unwrap();
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)).unwrap(),
            Event::Joined { .. }
        ));
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        s.flush().unwrap();
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            Event::Gone { .. } => {}
            _ => panic!("lost framing must tear the connection down"),
        }
        stop.store(true, Ordering::Release);
    }

    #[test]
    fn nb_writer_round_trips_under_load() {
        // Push enough data through an NbWriter to force WouldBlock retries
        // (the reader drains slowly), and verify byte integrity.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut got = Vec::new();
            let mut chunk = [0u8; 4096];
            loop {
                match s.read(&mut chunk) {
                    Ok(0) => break,
                    Ok(n) => {
                        got.extend_from_slice(&chunk[..n]);
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    Err(_) => break,
                }
            }
            got
        });
        let s = TcpStream::connect(addr).unwrap();
        s.set_nonblocking(true).unwrap();
        let mut w = NbWriter::new(s, 30.0);
        let payload: Vec<u8> = (0..2_000_000u32).map(|i| (i % 251) as u8).collect();
        w.write_all(&payload).unwrap();
        w.flush().unwrap();
        drop(w);
        let got = reader.join().unwrap();
        assert_eq!(got, payload, "NbWriter must deliver every byte in order");
    }
}
