//! The Photon deployment plane: a real multi-process federation runtime
//! over TCP (paper §4.1 — the Aggregator and LLM Nodes as *networked*
//! components, not threads; see also Photon, arXiv:2411.02908).
//!
//! * [`proto`]   — control protocol (Join/JoinAck + task spec, RoundAssign,
//!                 UpdatePush, Heartbeat, RoundCommit, Shutdown, Reject)
//!                 carried in Photon-Link frames with a version handshake
//! * [`server`]  — the Aggregator service: admits workers, replays the
//!                 exact sampler/fault schedule, enforces the per-round
//!                 straggler deadline, folds updates in sampled order
//!                 through a client-lease ledger (`chaos::LeaseBook`,
//!                 exactly-once), re-attaches rejoining workers to their
//!                 slot + in-flight leases, optionally migrates a dead or
//!                 silent worker's leases mid-round (`--migrate`), and
//!                 checkpoints every round for restart recovery
//! * [`worker`]  — the stateless LLM Node executor: pulls the model +
//!                 client state each round, runs the *same*
//!                 `ClientNode::run_local_round` the in-process federation
//!                 runs, pushes update + advanced state back; acts out the
//!                 injected chaos faults (crash/hang/slow/flake)
//! * [`poll`]    — nonblocking accept/read plane: one polling thread owns
//!                 every socket's read half (`set_nonblocking` + a ready
//!                 sweep over `std::net`, no extra dependencies) and
//!                 forwards Joined/Frame/Malformed/Gone events
//! * [`subagg`]  — the mid-tier sub-aggregator (`cfg.tiers > 1`): leases a
//!                 slice of each sampled cohort from the root, re-leases
//!                 it to downstream workers, folds the arrived updates in
//!                 slot order, pushes one `FoldedPush` upstream —
//!                 bit-identical to the in-process `tiered_fold`
//! * [`harness`] — deterministic in-process loopback fleet (with chaos
//!                 injection, rejoin loops, and a join watchdog) for
//!                 tests and the `photon exp distributed`/`exp chaos`
//!                 sweeps
//!
//! ## The invariant
//!
//! A localhost fleet of K workers reproduces `Federation::run` **bit for
//! bit** — same global model, same round records (wall-clock fields aside).
//! When faults strike (deadline cuts, worker crashes, rejoins, lease
//! migrations), the realized outcome is recorded as a `chaos::Trace` and
//! the run remains bit-reproducible in-process via
//! `Federation::run_trace`. The mechanism is server-owned client state:
//! workers receive every input (global model, stream cursors, KeepOpt
//! moments) with the assignment and return the advanced state with the
//! update, so a client whose worker vanishes is *exactly* a dropped
//! client — and a lease migrated to another worker computes the
//! *identical* bits, because worker identity never enters the math.
//!
//! CLI: `photon serve …` / `photon worker --connect host:port`; see the
//! README quickstart and `docs/ARCHITECTURE.md` ("Deployment plane").

pub mod harness;
pub mod poll;
pub mod proto;
pub mod server;
pub mod subagg;
pub mod worker;

pub use harness::{run_loopback, FleetOpts, FleetReport};
pub use poll::NbWriter;
pub use proto::{Msg, TaskSpec, PROTO_VERSION};
pub use server::{ServeOpts, Server};
pub use subagg::{run_subagg, SubaggOpts, SubaggReport};
pub use worker::{run_worker, WorkerOpts, WorkerReport};
