//! The deployment-plane Aggregator service: a [`Federation`] whose sampled
//! clients run on remote workers over TCP instead of the in-process round
//! engine (paper §4.1: "Photon offers a fully distributed infrastructure
//! for collaborative pre-training across institutions").
//!
//! ## Equivalence contract
//!
//! The server *is* a `Federation` — same sampler/fault replay
//! ([`Federation::plan_round`]), same streaming aggregation and outer step
//! ([`Federation::commit_round`]), same checkpoints. Workers are stateless
//! executors of [`crate::coordinator::ClientNode::run_local_round`] whose
//! inputs (global model, stream cursors, KeepOpt moments) are shipped per
//! round and whose outputs are folded in sampled order. A localhost fleet therefore reproduces
//! `Federation::run` bit-for-bit: same global model, same round records
//! (modulo wall-clock fields — see `RoundRecord::agrees_with`).
//!
//! ## Faults and elastic membership
//!
//! Every runnable client's round is a **lease** tracked in a
//! [`chaos::LeaseBook`]: dispatched to one worker, folded only from the
//! worker that currently holds it, at most once. On top of that ledger:
//!
//! * A per-round deadline (`ServeOpts::deadline_secs`) cuts stragglers:
//!   when it expires, pending clients drop from the aggregation exactly as
//!   sampler-dropped clients do, and their server-owned state stays at its
//!   pre-round value.
//! * A worker disconnect mid-round cuts its pending clients immediately
//!   when no deadline is configured (the PR 3 behavior). With a deadline,
//!   the leases stay pending until it fires — a **rejoining** worker
//!   (`Join.identity = slot + 1`) reclaims its slot and its in-flight
//!   leases and gets them re-dispatched at their unchanged pre-round
//!   state.
//! * With `ServeOpts::migrate`, leases move instead of waiting: a dead
//!   worker's pending clients are reassigned to live workers right away,
//!   and halfway to the deadline any connected worker that has pushed
//!   nothing has its unstarted clients reassigned too. Stale pushes from
//!   the previous holder are refused by the lease ledger (exactly-once).
//! * A frame that framed correctly but fails link decode (a flake) is
//!   skipped, not fatal: the affected client simply never arrives and is
//!   cut or migrated like any straggler — malformed ⇒ cut, never crash.
//!
//! Every realized cut is recorded in [`Server::cuts`], every realized
//! migration/rejoin next to it; [`Server::trace`] assembles the whole
//! [`chaos::Trace`], and `Federation::run_trace` replays the run
//! bit-exactly in-process. Because the federation checkpoints every
//! round, killing the server and restarting it with the same `--ckpt-dir`
//! resumes sample-exact (`Federation::try_resume_from`) — workers simply
//! reconnect and keep serving.
//!
//! ## Observability
//!
//! With an event sink installed on the federation (`fed.obs`, see the
//! [`crate::obs`] module and docs/OBSERVABILITY.md), the server emits a
//! structured JSONL event per join/rejoin, lease grant/fold, migration,
//! cut, malformed frame, stall, and round commit. Emission sites sit
//! exactly where the server pushes to its own `cuts`/`migrations`/
//! `rejoins` ledgers, so `obs::to_trace(log)` reconstructs
//! [`Server::trace`] bit-for-bit (`tests/props_obs.rs`).

// Wall-clock reads here are transport concerns (deadlines, liveness,
// session ids) — allowlisted; see docs/ANALYSIS.md (nondet-time).
#![allow(clippy::disallowed_methods)]

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::chaos::{self, LeaseBook, Migration};
use crate::ckpt::{ClientCkpt, StateStore};
use crate::coordinator::federation::{tier_slices, RoundDispatch};
use crate::coordinator::{ClientUpdate, Federation};
use crate::metrics::RoundRecord;
use crate::net::poll::{spawn_poller, Event, NbWriter};
use crate::net::proto::{
    self, AssignState, AssignTask, FoldedPush, JoinAck, Msg, Reject, RoundAssign,
    RoundCommit, TaskSpec, PROTO_VERSION,
};
use crate::obs::{self, Event as ObsEvent};

/// Deployment-plane service knobs.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Bind address, e.g. `127.0.0.1:7070` (`:0` picks a free port).
    pub bind: String,
    /// Wait for this many workers to join before dispatching round 0.
    pub min_workers: usize,
    /// Per-round straggler deadline in seconds (measured from dispatch);
    /// `None` disables the timer (disconnects still cut — immediately,
    /// since without a deadline there is no bounded rejoin window).
    pub deadline_secs: Option<f64>,
    /// Opt-in mid-round client-lease migration (requires a deadline): a
    /// dead or silent worker's unstarted clients are reassigned to live
    /// workers before the deadline cut. Realized migrations are recorded
    /// in [`Server::migrations`].
    pub migrate: bool,
    /// Deflate model payloads on the wire (lossless; bit-exact decode).
    pub compress: bool,
    /// How long to wait for the admission barrier before giving up.
    pub join_timeout_secs: f64,
    /// Socket write timeout — a worker that stops draining its socket for
    /// this long is declared dead and its pending clients are cut.
    pub io_timeout_secs: f64,
    /// Liveness backstop when no deadline is configured: a round with no
    /// progress for this long is cut (announced with a `Stall` event),
    /// not hung. The default keeps the historical hour.
    pub stall_secs: f64,
    /// Resident-byte budget for the server-owned client-state cache
    /// ([`StateStore`]); colder states spill to disk. `None` runs the
    /// store generation-only: assigns are served straight from the
    /// federation's own states (no second resident copy) and the store
    /// merely tracks the generations behind `AssignState::Ref`.
    pub state_budget: Option<u64>,
    /// Buffered asynchronous aggregation (`Some((k, gamma))`): drop the
    /// global round barrier and fold the first `k` arriving updates with
    /// staleness-discounted weights ([`chaos::discounted_weights`]),
    /// immediately re-leasing finished clients. Each commit is one
    /// **epoch**; `cfg.rounds` bounds the epoch count. Flat federations
    /// only (`cfg.tiers == 1`), incompatible with `migrate` (a grant is
    /// pinned to the worker that computes it), and requires
    /// `k <= cfg.n_clients` (a fold needs `k` distinct in-flight
    /// clients). The realized run is recorded in [`Server::async_trace`]
    /// and replays bit-exactly via `Federation::run_async_trace`.
    pub async_agg: Option<(usize, f64)>,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            bind: "127.0.0.1:7070".into(),
            min_workers: 1,
            deadline_secs: None,
            migrate: false,
            compress: true,
            join_timeout_secs: 120.0,
            io_timeout_secs: 30.0,
            stall_secs: 3600.0,
            state_budget: None,
            async_agg: None,
        }
    }
}

/// One admitted worker (or, in tree mode, sub-aggregator) connection:
/// the nonblocking write half plus the client-state generations this
/// connection provably holds (the basis for `AssignState::Ref`).
struct WorkerConn {
    conn: usize,
    name: String,
    stream: NbWriter,
    alive: bool,
    /// client → state generation this connection provably holds: shipped
    /// in a Full assign, or pushed back *and accepted*. Reset on admission
    /// and rejoin (a fresh process holds nothing), dropped per client on
    /// every push receipt until acceptance re-records it, and dropped on
    /// every cut — the worker's cache may have advanced past the server's
    /// authoritative pre-round state, and a `Ref` into that diverged copy
    /// would silently break the replay contract.
    gens: BTreeMap<usize, u64>,
}

/// The Photon Aggregator as a network service.
pub struct Server {
    fed: Federation,
    opts: ServeOpts,
    listener: Option<TcpListener>,
    addr: SocketAddr,
    session: u64,
    /// Memory-bounded transport cache of client states: with a
    /// `ServeOpts::state_budget` every assign is served from here
    /// (spilling LRU past the budget) and every accepted push refreshes
    /// it; without one it runs generation-only and assigns are served
    /// from the federation's states directly.
    store: StateStore,
    /// Realized deadline/disconnect cuts per round — the schedule that
    /// replays this run in-process via `Federation::run_round_cut`.
    pub cuts: Vec<(usize, Vec<usize>)>,
    /// Realized mid-round client-lease migrations per round (recorded
    /// next to `cuts`; they never affect the math, only who computed).
    pub migrations: Vec<(usize, Vec<Migration>)>,
    /// Realized worker rejoins as `(round, worker_slot)`.
    pub rejoins: Vec<(usize, usize)>,
    /// Flaked (framed-but-undecodable) frames dropped, for diagnostics.
    pub malformed_frames: u64,
    /// Async-plane ledgers (`ServeOpts::async_agg`): every grant
    /// dispatched, every fold committed, every grant cut — assembled into
    /// the replayable [`chaos::AsyncTrace`] by [`Server::async_trace`].
    async_grants: Vec<chaos::AsyncGrant>,
    async_folds: Vec<chaos::AsyncFold>,
    async_cuts: Vec<u64>,
}

impl Server {
    /// Bind the service around an existing federation (use
    /// `Federation::new` + `try_resume_from` for the restart path).
    pub fn with_federation(fed: Federation, opts: ServeOpts) -> Result<Server> {
        if opts.migrate {
            anyhow::ensure!(
                opts.deadline_secs.is_some(),
                "--migrate needs a per-round deadline (--deadline-secs) to bound \
                 the migration window"
            );
        }
        if let Some((k, gamma)) = opts.async_agg {
            anyhow::ensure!(
                fed.cfg.tiers == 1,
                "async aggregation is flat-mode only (tiers = {}): a grant's \
                 arrival order is the fold order, which a sub-aggregator tier \
                 would re-batch",
                fed.cfg.tiers
            );
            anyhow::ensure!(!opts.migrate, "async aggregation does not migrate leases");
            anyhow::ensure!(k >= 1, "async fold size k must be >= 1");
            anyhow::ensure!(
                k <= fed.cfg.n_clients,
                "async fold size k = {k} exceeds the {} clients available \
                 (a fold needs k distinct in-flight clients)",
                fed.cfg.n_clients
            );
            anyhow::ensure!(
                gamma > 0.0 && gamma <= 1.0,
                "staleness discount gamma must be in (0, 1], got {gamma}"
            );
        }
        anyhow::ensure!(
            opts.stall_secs > 0.0,
            "--stall-secs must be positive (it bounds the no-deadline liveness \
             backstop)"
        );
        let listener = TcpListener::bind(&opts.bind)
            .with_context(|| format!("binding {}", opts.bind))?;
        let addr = listener.local_addr()?;
        let session = fed.cfg.seed
            ^ std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0x5e55_1017);
        let spill_dir =
            std::env::temp_dir().join(format!("photon_spill_{session:016x}"));
        // With no budget the store runs generation-only: the federation
        // already holds every client state, so assigns are served from it
        // directly and the store just keeps the generation ledger behind
        // `AssignState::Ref` — no second resident copy, no spill files.
        let store = match opts.state_budget {
            Some(budget) => StateStore::new(budget, spill_dir),
            None => StateStore::gen_only(spill_dir),
        };
        Ok(Server {
            fed,
            opts,
            listener: Some(listener),
            addr,
            session,
            store,
            cuts: Vec::new(),
            migrations: Vec::new(),
            rejoins: Vec::new(),
            malformed_frames: 0,
            async_grants: Vec::new(),
            async_folds: Vec::new(),
            async_cuts: Vec::new(),
        })
    }

    /// The transport-layer client-state cache (resident/spill statistics).
    pub fn state_store(&self) -> &StateStore {
        &self.store
    }

    /// The bound address (useful with `bind: "127.0.0.1:0"`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn federation(&self) -> &Federation {
        &self.fed
    }

    pub fn federation_mut(&mut self) -> &mut Federation {
        &mut self.fed
    }

    fn emit(&self, ev: ObsEvent) {
        if let Some(sink) = &self.fed.obs {
            sink.emit(ev);
        }
    }

    /// The realized chaos trace of this run — cuts, migrations, and
    /// rejoins per round, replayable bit-exactly with
    /// `Federation::run_trace`.
    pub fn trace(&self) -> chaos::Trace {
        fn entry(
            rounds: &mut BTreeMap<usize, chaos::RoundTrace>,
            r: usize,
        ) -> &mut chaos::RoundTrace {
            rounds
                .entry(r)
                .or_insert_with(|| chaos::RoundTrace { round: r, ..Default::default() })
        }
        let mut rounds: BTreeMap<usize, chaos::RoundTrace> = BTreeMap::new();
        for (r, c) in &self.cuts {
            entry(&mut rounds, *r).cut = c.clone();
        }
        for (r, m) in &self.migrations {
            entry(&mut rounds, *r).migrations = m.clone();
        }
        for (r, s) in &self.rejoins {
            entry(&mut rounds, *r).rejoined.push(*s);
        }
        chaos::Trace { rounds: rounds.into_values().collect() }
    }

    /// The realized async-plane trace of this run (grants, folds,
    /// staleness, discounted weights, cuts) — `None` unless the server
    /// ran with `ServeOpts::async_agg`. Replayable bit-exactly with
    /// `Federation::run_async_trace`.
    pub fn async_trace(&self) -> Option<chaos::AsyncTrace> {
        let (k, gamma) = self.opts.async_agg?;
        Some(chaos::AsyncTrace {
            k,
            gamma,
            grants: self.async_grants.clone(),
            folds: self.async_folds.clone(),
            cut: self.async_cuts.clone(),
        })
    }

    /// The task spec shipped to joining workers: everything a stateless
    /// worker needs to run local rounds bit-identically.
    fn task_spec(&self) -> TaskSpec {
        let cfg = &self.fed.cfg;
        let islands =
            crate::cluster::island::island_counts(cfg.fleet.as_ref(), cfg.n_clients);
        TaskSpec {
            model: cfg.model.clone(),
            n_params: self.fed.global.len() as u64,
            corpus: cfg.corpus.clone(),
            n_clients: cfg.n_clients as u64,
            seed: cfg.seed,
            schedule: cfg.schedule,
            opt_state: cfg.opt_state,
            islands: islands.iter().map(|&i| i as u32).collect(),
            compress: self.opts.compress,
            codec: cfg.codec,
        }
    }

    /// Admit a fresh worker (or sub-aggregator), or re-attach a returning
    /// one to its old slot (`Join.identity = slot + 1`). Returns
    /// `Some(slot)` on a successful rejoin so the round loop can
    /// re-dispatch the reclaimed leases.
    ///
    /// Peer-kind routing: a tiered federation (`cfg.tiers > 1`) only
    /// admits `SubJoin` peers — plain workers must connect to a
    /// sub-aggregator — and a flat one only admits plain `Join`s.
    fn admit_or_rejoin(
        &mut self,
        workers: &mut Vec<WorkerConn>,
        conn: usize,
        stream: TcpStream,
        join: proto::Join,
        sub: bool,
    ) -> Option<usize> {
        let mut stream = NbWriter::new(stream, self.opts.io_timeout_secs);
        if join.proto != PROTO_VERSION {
            let reject = Msg::Reject(Reject {
                reason: format!(
                    "worker speaks photon-net v{}, server requires v{PROTO_VERSION}",
                    join.proto
                ),
            });
            let _ = proto::write_msg(&mut stream, &reject, false);
            return None;
        }
        let tree = self.fed.cfg.tiers > 1;
        if sub != tree {
            let reason = if tree {
                "root is in tree mode: workers must connect to a sub-aggregator"
                    .to_string()
            } else {
                "flat federation: sub-aggregators are not admitted (set --tiers)"
                    .to_string()
            };
            let _ = proto::write_msg(&mut stream, &Msg::Reject(Reject { reason }), false);
            return None;
        }
        if join.identity > 0 {
            // Rejoin path: the identity must name a slot this incarnation
            // assigned and that is currently dead — a live slot means the
            // identity is stolen or stale, and an unknown one belongs to a
            // previous server life (state is in the checkpoint, not here).
            let slot = (join.identity - 1) as usize;
            if slot >= workers.len() || workers[slot].alive {
                let reject = Msg::Reject(Reject {
                    reason: format!(
                        "identity {} does not name a reclaimable worker slot",
                        join.identity
                    ),
                });
                let _ = proto::write_msg(&mut stream, &reject, false);
                return None;
            }
            let ack = Msg::JoinAck(JoinAck {
                proto: PROTO_VERSION,
                session: self.session,
                worker_slot: slot as u64,
                spec: self.task_spec(),
            });
            if proto::write_msg(&mut stream, &ack, false).is_err() {
                return None;
            }
            println!(
                "[serve] worker {:?} rejoined slot {slot} (round {})",
                join.name, self.fed.next_round
            );
            workers[slot] = WorkerConn {
                conn,
                name: join.name,
                stream,
                alive: true,
                // A rejoined process holds no cached states: everything it
                // is assigned from here on ships Full until it pushes.
                gens: BTreeMap::new(),
            };
            self.rejoins.push((self.fed.next_round, slot));
            self.emit(ObsEvent::WorkerRejoin {
                round: self.fed.next_round as u64,
                worker: slot as u64,
                name: workers[slot].name.clone(),
            });
            return Some(slot);
        }
        let ack = Msg::JoinAck(JoinAck {
            proto: PROTO_VERSION,
            session: self.session,
            worker_slot: workers.len() as u64,
            spec: self.task_spec(),
        });
        if proto::write_msg(&mut stream, &ack, false).is_err() {
            return None;
        }
        if sub {
            println!(
                "[serve] admitted sub-aggregator {:?} (slot {})",
                join.name,
                workers.len()
            );
            self.emit(ObsEvent::SubaggJoin {
                subagg: workers.len() as u64,
                name: join.name.clone(),
            });
        } else {
            println!("[serve] admitted worker {:?} (slot {})", join.name, workers.len());
            self.emit(ObsEvent::WorkerJoin {
                worker: workers.len() as u64,
                name: join.name.clone(),
            });
        }
        workers.push(WorkerConn {
            conn,
            name: join.name,
            stream,
            alive: true,
            gens: BTreeMap::new(),
        });
        None
    }

    /// Serve the whole training run: admit ≥ `min_workers`, dispatch every
    /// remaining round, fold updates, checkpoint, and shut the fleet down.
    /// Returns the complete round-record log (the same shape
    /// `Federation::run` returns).
    pub fn run(&mut self) -> Result<Vec<RoundRecord>> {
        let listener = self
            .listener
            .take()
            .ok_or_else(|| anyhow::anyhow!("Server::run may only be called once"))?;
        let (tx, rx) = mpsc::channel::<Event>();
        let stop = Arc::new(AtomicBool::new(false));
        spawn_poller(listener, tx, stop.clone())?;
        self.emit(ObsEvent::ServerStart {
            session: format!("{:#x}", self.session),
            rounds: self.fed.cfg.rounds as u64,
            n_clients: self.fed.cfg.n_clients as u64,
            clients_per_round: self.fed.cfg.clients_per_round as u64,
        });

        let mut workers: Vec<WorkerConn> = Vec::new();
        let result = self.run_rounds(&rx, &mut workers);

        // Clean shutdown regardless of outcome: tell live workers, then
        // stop the polling thread (it checks the flag every sweep, so no
        // wakeup connection is needed).
        for w in workers.iter_mut().filter(|w| w.alive) {
            let _ = proto::write_msg(&mut w.stream, &Msg::Shutdown, false);
        }
        stop.store(true, Ordering::Release);
        self.emit(ObsEvent::Shutdown { rounds: self.fed.next_round as u64 });
        // The store is a transport cache (the federation and its
        // checkpoints are authoritative) — remove its spill files so
        // long-lived hosts don't accumulate state_*.bin across runs.
        self.store.cleanup();

        result?;
        Ok(self.fed.log.rounds.clone())
    }

    fn run_rounds(
        &mut self,
        rx: &Receiver<Event>,
        workers: &mut Vec<WorkerConn>,
    ) -> Result<()> {
        // Admission barrier.
        let join_deadline =
            Instant::now() + Duration::from_secs_f64(self.opts.join_timeout_secs);
        while workers.iter().filter(|w| w.alive).count() < self.opts.min_workers {
            let now = Instant::now();
            if now >= join_deadline {
                bail!(
                    "timed out waiting for {} workers ({} joined)",
                    self.opts.min_workers,
                    workers.len()
                );
            }
            match rx.recv_timeout(join_deadline - now) {
                Ok(Event::Joined { conn, stream, join, sub }) => {
                    self.admit_or_rejoin(workers, conn, stream, join, sub);
                }
                Ok(Event::Gone { conn }) => mark_gone(workers, conn),
                Ok(Event::Frame { .. }) | Ok(Event::Malformed { .. }) => {}
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("polling thread died"),
            }
        }

        if self.opts.async_agg.is_some() {
            return self.serve_async(rx, workers);
        }
        while self.fed.next_round < self.fed.cfg.rounds {
            self.serve_round(rx, workers)?;
        }
        Ok(())
    }

    /// Block until at least one worker is alive (a crashed fleet may be
    /// mid-rejoin), up to the join timeout.
    fn await_live_worker(
        &mut self,
        rx: &Receiver<Event>,
        workers: &mut Vec<WorkerConn>,
        round: usize,
    ) -> Result<()> {
        let give_up = Instant::now() + Duration::from_secs_f64(self.opts.join_timeout_secs);
        while !workers.iter().any(|w| w.alive) {
            let now = Instant::now();
            if now >= give_up {
                bail!(
                    "no connected workers left at round {round} (state is \
                     checkpointed; restart with --resume)"
                );
            }
            match rx.recv_timeout(give_up - now) {
                Ok(Event::Joined { conn, stream, join, sub }) => {
                    self.admit_or_rejoin(workers, conn, stream, join, sub);
                }
                Ok(Event::Gone { conn }) => mark_gone(workers, conn),
                Ok(_) => {}
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("polling thread died"),
            }
        }
        Ok(())
    }

    /// The state field for assigning `c` to connection `w`: a generation
    /// reference when the connection provably holds the current state
    /// (it received or pushed this exact generation earlier), the full
    /// bytes otherwise. Tree mode always ships Full — a sub-aggregator
    /// re-leases the task to a worker of its own, which holds nothing the
    /// root knows about.
    fn assign_state(&mut self, w: &mut WorkerConn, c: usize) -> Result<AssignState> {
        let gen = match self.store.gen_of(c) {
            Some(g) => g,
            None => self.store.put(c, &self.fed.client_state(c))?,
        };
        if self.fed.cfg.tiers == 1 && w.gens.get(&c) == Some(&gen) {
            return Ok(AssignState::Ref(gen));
        }
        let state = match self.store.get(c)? {
            Some(s) => s,
            None => self.fed.client_state(c),
        };
        w.gens.insert(c, gen);
        Ok(AssignState::Full(state))
    }

    /// Re-dispatch `clients` (at their unchanged pre-round state) to
    /// worker `widx` — the rejoin/migration delivery. On a write failure
    /// the worker is marked dead and the leases stay pending for the
    /// deadline (or the next rejoin) to resolve.
    fn send_assign(
        &mut self,
        workers: &mut [WorkerConn],
        widx: usize,
        clients: &[usize],
        d: &RoundDispatch,
        steps_of: &BTreeMap<usize, u64>,
    ) -> Result<()> {
        if clients.is_empty() {
            return Ok(());
        }
        let mut tasks: Vec<AssignTask> = Vec::with_capacity(clients.len());
        for &c in clients {
            let state = self.assign_state(&mut workers[widx], c)?;
            tasks.push(AssignTask { client: c as u64, steps: steps_of[&c], state });
        }
        let msg = Msg::RoundAssign(RoundAssign {
            session: self.session,
            round: d.round as u64,
            seq_base: d.seq_base,
            // Sync rounds pin the lease epoch to the round number (v5);
            // only the async plane gives it independent meaning.
            lease_epoch: d.round as u64,
            tasks,
            global: self.fed.global.clone(),
        });
        if proto::write_msg(&mut workers[widx].stream, &msg, self.opts.compress).is_err() {
            workers[widx].alive = false;
        }
        Ok(())
    }

    /// Dispatch one async grant (a single-client work order) to worker
    /// `widx`. The wire `round` field carries the grant id and
    /// `lease_epoch` the dispatch epoch (proto v5); `seq_base` was frozen
    /// into the grant at creation so replay needs no server clock.
    fn send_grant(
        &mut self,
        workers: &mut [WorkerConn],
        widx: usize,
        g: &chaos::AsyncGrant,
    ) -> Result<()> {
        let state = self.assign_state(&mut workers[widx], g.client)?;
        let msg = Msg::RoundAssign(RoundAssign {
            session: self.session,
            round: g.grant,
            seq_base: g.seq_base,
            lease_epoch: g.born_epoch,
            tasks: vec![AssignTask { client: g.client as u64, steps: g.steps, state }],
            global: self.fed.global.clone(),
        });
        if proto::write_msg(&mut workers[widx].stream, &msg, self.opts.compress).is_err() {
            workers[widx].alive = false;
        }
        Ok(())
    }

    /// Cut one in-flight async grant (disconnect, malformed push, or
    /// deadline). The client's server-owned state is untouched — the
    /// dropped-client semantics — and every connection's generation claim
    /// for it is dropped so its next grant ships Full, never a `Ref` into
    /// a diverged worker cache.
    fn cut_grant(
        &mut self,
        workers: &mut [WorkerConn],
        book: &mut chaos::AsyncBook,
        grants: &BTreeMap<u64, chaos::AsyncGrant>,
        grant: u64,
    ) {
        if !book.cut(grant) {
            return;
        }
        if let Some(g) = grants.get(&grant) {
            for w in workers.iter_mut() {
                w.gens.remove(&g.client);
            }
            self.emit(ObsEvent::Cut {
                round: self.fed.next_round as u64,
                clients: vec![g.client as u64],
            });
        }
    }

    /// Close one async epoch: drain the `k` buffered arrivals in
    /// canonical (ascending grant id) order, fold them with staleness-
    /// discounted weights, install the folded states, release their
    /// clients for fresh grants, and broadcast the commit.
    fn commit_async(
        &mut self,
        workers: &mut [WorkerConn],
        book: &mut chaos::AsyncBook,
        grants: &BTreeMap<u64, chaos::AsyncGrant>,
        buffer: &mut BTreeMap<u64, (ClientUpdate, ClientCkpt)>,
        k: usize,
        gamma: f64,
        t_epoch: &mut Instant,
    ) -> Result<()> {
        let epoch = self.fed.next_round as u64;
        // BTreeMap iteration order IS the canonical fold order.
        let keys: Vec<u64> = buffer.keys().copied().take(k).collect();
        let mut entries = Vec::with_capacity(keys.len());
        for key in keys {
            let v = buffer.remove(&key).expect("key just listed");
            entries.push((key, v));
        }
        let staleness: Vec<u64> = entries
            .iter()
            .map(|(g, _)| {
                let born = grants.get(g).map(|gr| gr.born_epoch).unwrap_or(epoch);
                epoch.saturating_sub(born)
            })
            .collect();
        let base: Vec<f64> = entries.iter().map(|(_, (u, _))| u.n_samples).collect();
        let weights = chaos::discounted_weights(&base, &staleness, gamma);
        let arrivals: Vec<chaos::AsyncArrival> = entries
            .iter()
            .zip(staleness.iter().zip(&weights))
            .map(|((g, (u, _)), (&s, &w))| chaos::AsyncArrival {
                grant: *g,
                client: u.client_id,
                staleness: s,
                weight: w,
            })
            .collect();
        self.emit(ObsEvent::AsyncFold {
            epoch,
            k: arrivals.len() as u64,
            clients: arrivals.iter().map(|a| a.client as u64).collect(),
            staleness_max: staleness.iter().copied().max().unwrap_or(0),
        });
        self.async_folds.push(chaos::AsyncFold { epoch, arrivals });
        let mut updates: Vec<ClientUpdate> = Vec::with_capacity(entries.len());
        for (g, (update, state)) in entries {
            self.fed
                .restore_client_state(update.client_id, &state)
                .with_context(|| format!("installing client {} state", update.client_id))?;
            if !book.release(g, update.client_id) {
                bail!("async ledger lost the arrival backing grant {g}");
            }
            updates.push(update);
        }
        let rec = self.fed.commit_async_fold(
            epoch as usize,
            updates,
            &staleness,
            &weights,
            gamma,
            *t_epoch,
        )?;
        *t_epoch = Instant::now();
        println!(
            "[serve] epoch {:>3}  server_ppl {:>9.3}  folded {}  staleness_max {}",
            rec.round,
            rec.server_ppl,
            rec.participated,
            self.async_folds
                .last()
                .map(|f| f.arrivals.iter().map(|a| a.staleness).max().unwrap_or(0))
                .unwrap_or(0),
        );
        obs::timing("serve", &format!("epoch {}", rec.round), rec.wall_secs);
        let commit = Msg::RoundCommit(RoundCommit {
            round: rec.round as u64,
            participated: rec.participated as u64,
            global_norm: rec.global_model_norm,
        });
        for w in workers.iter_mut().filter(|w| w.alive) {
            if proto::write_msg(&mut w.stream, &commit, false).is_err() {
                w.alive = false;
            }
        }
        Ok(())
    }

    /// Buffered asynchronous aggregation (`ServeOpts::async_agg`): no
    /// round barrier. The server keeps up to `max(k, live_workers)`
    /// single-client grants in flight (round-robin over the non-busy
    /// clients — the per-round sampler is not consulted), buffers the
    /// arriving updates, and commits an epoch the moment `k` of them are
    /// buffered. A client whose grant is buffered stays busy until the
    /// fold installs its advanced state (per-client serialization — a
    /// concurrent second grant would ship a stale state and break the
    /// replay contract). Crashed, malformed, and deadline-expired grants
    /// are cut (server state untouched) and their clients re-granted
    /// fresh at the current epoch. Runs until `cfg.rounds` epochs commit;
    /// grants still in flight at that point are cut into the trace.
    fn serve_async(
        &mut self,
        rx: &Receiver<Event>,
        workers: &mut Vec<WorkerConn>,
    ) -> Result<()> {
        let Some((k, gamma)) = self.opts.async_agg else {
            bail!("serve_async without ServeOpts::async_agg");
        };
        let n_clients = self.fed.cfg.n_clients;
        let steps = self.fed.cfg.local_steps;
        let mut book = chaos::AsyncBook::default();
        // Every grant ever dispatched, by id (steps/epoch lookups).
        let mut grants: BTreeMap<u64, chaos::AsyncGrant> = BTreeMap::new();
        // Accepted-but-unfolded arrivals, keyed by grant id.
        let mut buffer: BTreeMap<u64, (ClientUpdate, ClientCkpt)> = BTreeMap::new();
        let mut dispatch_at: BTreeMap<u64, Instant> = BTreeMap::new();
        let mut next_grant: u64 = 0;
        let mut cursor: usize = 0;
        let mut t_epoch = Instant::now();

        while self.fed.next_round < self.fed.cfg.rounds {
            self.await_live_worker(rx, workers, self.fed.next_round)?;
            // Top up the in-flight pool.
            loop {
                let live: Vec<usize> =
                    (0..workers.len()).filter(|&i| workers[i].alive).collect();
                if live.is_empty()
                    || book.pending_count() + buffer.len() >= k.max(live.len())
                {
                    break;
                }
                // Next non-busy client, round-robin; all busy ⇒ the pool
                // is as full as the client population allows.
                let Some(client) = (0..n_clients)
                    .map(|_| {
                        let c = cursor % n_clients;
                        cursor += 1;
                        c
                    })
                    .find(|&c| !book.is_busy(c))
                else {
                    break;
                };
                // Least-loaded live worker (ties → lowest slot).
                let widx = live
                    .iter()
                    .copied()
                    .min_by_key(|&w| (book.pending_of(w).len(), w))
                    .expect("live is non-empty");
                let g = chaos::AsyncGrant {
                    grant: next_grant,
                    client,
                    steps,
                    born_epoch: self.fed.next_round as u64,
                    seq_base: self.fed.seq_step,
                };
                next_grant += 1;
                if !book.grant(g.grant, client, widx, g.born_epoch) {
                    bail!("async ledger refused fresh grant {}", g.grant);
                }
                grants.insert(g.grant, g);
                self.async_grants.push(g);
                dispatch_at.insert(g.grant, Instant::now());
                self.emit(ObsEvent::LeaseGrant {
                    round: g.grant,
                    client: client as u64,
                    worker: widx as u64,
                });
                self.send_grant(workers, widx, &g)?;
                if !workers[widx].alive {
                    // The write failed — the grant never reached a worker.
                    self.cut_grant(workers, &mut book, &grants, g.grant);
                    dispatch_at.remove(&g.grant);
                }
            }

            let now = Instant::now();
            let deadline = self.opts.deadline_secs.map(Duration::from_secs_f64);
            if let Some(dl) = deadline {
                // Per-grant deadline, measured from dispatch.
                let expired: Vec<u64> = book
                    .pending_ids()
                    .into_iter()
                    .filter(|g| {
                        dispatch_at.get(g).is_some_and(|&t| now >= t + dl)
                    })
                    .collect();
                if !expired.is_empty() {
                    for g in expired {
                        println!(
                            "[serve] async: grant {g} pending past the deadline — \
                             cutting"
                        );
                        self.cut_grant(workers, &mut book, &grants, g);
                        dispatch_at.remove(&g);
                    }
                    continue; // top-up re-grants the freed clients
                }
            }
            let timer = deadline.and_then(|dl| {
                book.pending_ids()
                    .into_iter()
                    .filter_map(|g| dispatch_at.get(&g).map(|&t| t + dl))
                    .min()
            });
            let timeout = match timer {
                Some(t) => t.saturating_duration_since(now),
                None => Duration::from_secs_f64(self.opts.stall_secs),
            };
            match rx.recv_timeout(timeout) {
                Ok(Event::Joined { conn, stream, join, sub }) => {
                    // Fresh joins and identity rejoins both just enlarge
                    // the live pool: a crashed worker's grants were cut at
                    // disconnect, so there is nothing to reclaim — the
                    // next top-up hands the rejoined worker fresh grants.
                    let _ = self.admit_or_rejoin(workers, conn, stream, join, sub);
                }
                Ok(Event::Frame { conn, msg }) => match msg {
                    Msg::UpdatePush(p) if p.session == self.session => {
                        let grant = p.round;
                        let Some(widx) = workers.iter().position(|w| w.conn == conn)
                        else {
                            continue;
                        };
                        let client = p.update.client_id;
                        // Same cache hygiene as the sync path: any push
                        // overwrote the sender's local state copy; only an
                        // accepted push re-establishes the claim.
                        workers[widx].gens.remove(&client);
                        if book.owner(grant) != Some(widx) {
                            continue; // stale/duplicate push — exactly-once
                        }
                        let Some(g) = grants.get(&grant).copied() else {
                            continue;
                        };
                        // Decode-then-fold plus the v5 echo checks: the
                        // push must name the granted client and echo the
                        // dispatch epoch.
                        let codec = self.fed.cfg.codec;
                        let mut update = p.update;
                        let reconstructed: Option<u64> = match (codec.is_lossy(), &p.body)
                        {
                            (false, None) => {
                                Some(crate::link::dense_frame_bytes(update.params.len()))
                            }
                            (true, Some(body)) if update.params.is_empty() => {
                                match crate::compress::decode_transit(
                                    &codec,
                                    &self.fed.global,
                                    body,
                                ) {
                                    Ok(params) => {
                                        update.params = params;
                                        Some(crate::link::framed_bytes(body.len()))
                                    }
                                    Err(_) => None,
                                }
                            }
                            _ => None,
                        };
                        let ok = reconstructed.is_some()
                            && update.params.len() == self.fed.global.len()
                            && client == g.client
                            && p.lease_epoch == g.born_epoch
                            && self.fed.check_client_state(client, &p.state).is_ok();
                        if !ok {
                            self.cut_grant(workers, &mut book, &grants, grant);
                            dispatch_at.remove(&grant);
                            continue;
                        }
                        update.wire_bytes = reconstructed.unwrap_or(0);
                        if book.accept(grant, widx) {
                            dispatch_at.remove(&grant);
                            let gen = self.store.put(client, &p.state)?;
                            workers[widx].gens.insert(client, gen);
                            self.emit(ObsEvent::LeaseFold {
                                round: grant,
                                client: client as u64,
                                worker: widx as u64,
                            });
                            buffer.insert(grant, (update, p.state));
                        }
                    }
                    // Heartbeats, stale-session pushes.
                    _ => {}
                },
                Ok(Event::Malformed { conn }) => {
                    self.malformed_frames += 1;
                    let widx = workers.iter().position(|w| w.conn == conn);
                    let who = widx.map(|w| workers[w].name.as_str()).unwrap_or("?");
                    println!(
                        "[serve] epoch {}: dropped undecodable frame from {who:?}",
                        self.fed.next_round
                    );
                    self.emit(ObsEvent::Malformed {
                        round: self.fed.next_round as u64,
                        worker: widx.map(|w| w as u64),
                    });
                }
                Ok(Event::Gone { conn }) => {
                    mark_gone(workers, conn);
                    if let Some(widx) = workers.iter().position(|w| w.conn == conn) {
                        // A dead worker's in-flight grants are cut now —
                        // their clients re-grant fresh at the current
                        // epoch (exactly-once per grant; already-buffered
                        // arrivals from this worker are unaffected: the
                        // server holds their data).
                        for g in book.pending_of(widx) {
                            self.cut_grant(workers, &mut book, &grants, g);
                            dispatch_at.remove(&g);
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    if deadline.is_none() {
                        let pending = book.pending_ids();
                        if !pending.is_empty() {
                            println!(
                                "[serve] async: stall backstop ({}s) fired with {} \
                                 grant(s) in flight — cutting",
                                self.opts.stall_secs,
                                pending.len()
                            );
                            self.emit(ObsEvent::Stall {
                                round: Some(self.fed.next_round as u64),
                                waited_us: (self.opts.stall_secs * 1e6) as u64,
                                detail: format!(
                                    "{} grant(s) in flight past the liveness backstop",
                                    pending.len()
                                ),
                            });
                            for g in pending {
                                self.cut_grant(workers, &mut book, &grants, g);
                                dispatch_at.remove(&g);
                            }
                        }
                    }
                }
                Err(RecvTimeoutError::Disconnected) => bail!("polling thread died"),
            }
            if buffer.len() >= k {
                self.commit_async(
                    workers, &mut book, &grants, &mut buffer, k, gamma, &mut t_epoch,
                )?;
            }
        }
        // Epoch budget exhausted. The run ends right after a fold (the
        // buffer is empty); grants still in flight never folded — cut
        // them into the trace so replay skips them.
        for g in book.pending_ids() {
            let _ = book.cut(g);
        }
        self.async_cuts = book.cuts();
        Ok(())
    }

    /// Move every pending lease of `from` onto the given live targets and
    /// re-dispatch them. Records the realized migrations.
    fn migrate_pending(
        &mut self,
        workers: &mut [WorkerConn],
        book: &mut LeaseBook,
        d: &RoundDispatch,
        steps_of: &BTreeMap<usize, u64>,
        from: usize,
        targets: &[usize],
        migs: &mut Vec<Migration>,
    ) -> Result<()> {
        let moved = book.migrate_from(from, targets);
        if moved.is_empty() {
            return Ok(());
        }
        println!(
            "[serve] round {}: migrating {} lease(s) off worker {:?} (slot {from})",
            d.round,
            moved.len(),
            workers[from].name
        );
        for (widx, clients) in LeaseBook::group_by_target(&moved) {
            self.send_assign(workers, widx, &clients, d, steps_of)?;
        }
        for m in &moved {
            self.emit(ObsEvent::Migration {
                round: d.round as u64,
                client: m.client as u64,
                from: m.from as u64,
                to: m.to as u64,
            });
        }
        migs.extend(moved);
        Ok(())
    }

    /// Dispatch, collect, and commit one round.
    fn serve_round(&mut self, rx: &Receiver<Event>, workers: &mut Vec<WorkerConn>) -> Result<()> {
        if self.fed.cfg.tiers > 1 {
            return self.serve_round_tree(rx, workers);
        }
        let t0 = Instant::now();
        self.await_live_worker(rx, workers, self.fed.next_round)?;
        let d = self.fed.plan_round();
        let live: Vec<usize> =
            (0..workers.len()).filter(|&i| workers[i].alive).collect();

        // Static per-round partition of the runnable clients over the live
        // workers, in slot order. Which worker runs a client never affects
        // the math — all state travels with the assignment.
        let mut book = LeaseBook::new(&d.runnable);
        let steps_of: BTreeMap<usize, u64> = d.runnable.iter().copied().collect();
        let mut per_worker: Vec<Vec<usize>> = vec![Vec::new(); workers.len()];
        for (slot, &(client, _)) in d.runnable.iter().enumerate() {
            let widx = live[slot % live.len()];
            book.lease(client, widx);
            self.emit(ObsEvent::LeaseGrant {
                round: d.round as u64,
                client: client as u64,
                worker: widx as u64,
            });
            per_worker[widx].push(client);
        }

        let deadline = self
            .opts
            .deadline_secs
            .map(|s| t0 + Duration::from_secs_f64(s));
        // Opt-in straggler migration fires once, halfway to the deadline.
        let mut migrate_at = match (self.opts.migrate, self.opts.deadline_secs) {
            (true, Some(s)) => Some(t0 + Duration::from_secs_f64(s / 2.0)),
            _ => None,
        };
        let mut round_migs: Vec<Migration> = Vec::new();
        // Progress signal per worker slot: pushes received this round
        // (valid or not) — a worker with leases and zero pushes at the
        // halfway mark is treated as hung and migrated away from.
        // (Keyed, not indexed: workers admitted mid-round grow the list.)
        let mut pushed_by: BTreeMap<usize, u64> = BTreeMap::new();

        for &widx in &live {
            let clients = std::mem::take(&mut per_worker[widx]);
            if clients.is_empty() {
                continue;
            }
            self.send_assign(workers, widx, &clients, &d, &steps_of)?;
            if !workers[widx].alive && deadline.is_none() {
                // Worker unreachable at dispatch and no rejoin window: cut
                // its share now (the PR 3 semantics).
                let _ = book.cut_pending_of(widx);
            }
        }

        // Collect updates until everyone answered, the deadline fires, or
        // the owning workers die.
        let mut arrived: BTreeMap<usize, (ClientUpdate, ClientCkpt)> = BTreeMap::new();
        while book.pending_count() > 0 {
            let now = Instant::now();
            if let Some(dl) = deadline {
                if now >= dl {
                    book.cut_all_pending();
                    break;
                }
            }
            if let Some(m) = migrate_at {
                if now >= m {
                    migrate_at = None;
                    // Any live worker with leases but no pushes yet is
                    // treated as a silent straggler; its unstarted clients
                    // move to the live workers that are making progress.
                    let silent: Vec<usize> = (0..workers.len())
                        .filter(|&w| {
                            workers[w].alive
                                && pushed_by.get(&w).copied().unwrap_or(0) == 0
                                && !book.pending_of(w).is_empty()
                        })
                        .collect();
                    let targets: Vec<usize> = (0..workers.len())
                        .filter(|&w| workers[w].alive && !silent.contains(&w))
                        .collect();
                    for from in silent {
                        self.migrate_pending(
                            workers, &mut book, &d, &steps_of, from, &targets,
                            &mut round_migs,
                        )?;
                    }
                    continue;
                }
            }
            // Wait until the next event or the nearest timer.
            let timer = [deadline, migrate_at].into_iter().flatten().min();
            let timeout = match timer {
                Some(t) => t.saturating_duration_since(now),
                // Liveness backstop: with no deadline configured, a round
                // that makes no progress for `stall_secs` is cut, not hung.
                None => Duration::from_secs_f64(self.opts.stall_secs),
            };
            match rx.recv_timeout(timeout) {
                Ok(Event::Joined { conn, stream, join, sub }) => {
                    // Mid-round joins are admitted (work from the next
                    // round on); mid-round REjoins reclaim their pending
                    // leases and get them re-dispatched immediately.
                    if let Some(widx) =
                        self.admit_or_rejoin(workers, conn, stream, join, sub)
                    {
                        let reclaimed = book.pending_of(widx);
                        self.send_assign(workers, widx, &reclaimed, &d, &steps_of)?;
                    }
                }
                Ok(Event::Frame { conn, msg }) => match msg {
                    Msg::UpdatePush(p)
                        if p.session == self.session && p.round == d.round as u64 =>
                    {
                        let client = p.update.client_id;
                        let Some(widx) = workers.iter().position(|w| w.conn == conn)
                        else {
                            continue;
                        };
                        *pushed_by.entry(widx).or_insert(0) += 1;
                        // Any push means the sender overwrote its local
                        // cache for this client with the advanced state it
                        // just computed. That copy is authoritative only if
                        // this exact push is accepted below — so drop the
                        // connection's generation claim now and let the
                        // acceptance path re-establish it. Otherwise a
                        // later round could ship `Ref` into a cache that
                        // silently diverged from the server's pre-round
                        // state (rejected push, stale holder, late
                        // straggler).
                        workers[widx].gens.remove(&client);
                        // Only the current lease holder may answer for a
                        // client — a push from anyone else (rogue peer,
                        // stale reconnect, migrated-away straggler) is
                        // discarded without touching the ledger.
                        if book.owner(client) != Some(widx) {
                            continue;
                        }
                        // Decode-then-fold: rebuild dense params from the
                        // negotiated update codec. The push must match the
                        // negotiation's shape exactly — a dense push where
                        // a coded one was negotiated (or vice versa), a
                        // codec-id mismatch, or any structural defect in
                        // the coded body makes this None.
                        let codec = self.fed.cfg.codec;
                        let mut update = p.update;
                        let reconstructed: Option<u64> = match (codec.is_lossy(), &p.body)
                        {
                            (false, None) => {
                                Some(crate::link::dense_frame_bytes(update.params.len()))
                            }
                            (true, Some(body)) if update.params.is_empty() => {
                                match crate::compress::decode_transit(
                                    &codec,
                                    &self.fed.global,
                                    body,
                                ) {
                                    Ok(params) => {
                                        update.params = params;
                                        Some(crate::link::framed_bytes(body.len()))
                                    }
                                    Err(_) => None,
                                }
                            }
                            _ => None,
                        };
                        let ok = reconstructed.is_some()
                            && update.params.len() == self.fed.global.len()
                            && self.fed.check_client_state(client, &p.state).is_ok();
                        if !ok {
                            // Malformed push from the lease holder: the
                            // update cannot be folded — cut the client
                            // through the dropped path, don't kill the run.
                            book.cut(client);
                            continue;
                        }
                        update.wire_bytes = reconstructed.unwrap_or(0);
                        if book.accept(client, widx) {
                            let Some(slot) = book.slot(client) else {
                                bail!("lease ledger accepted unsampled client {client}");
                            };
                            // Record the advanced state: the pushing
                            // connection now provably holds this exact
                            // generation, so the next round's assign can be
                            // a Ref instead of the full bytes.
                            let gen = self.store.put(client, &p.state)?;
                            workers[widx].gens.insert(client, gen);
                            self.emit(ObsEvent::LeaseFold {
                                round: d.round as u64,
                                client: client as u64,
                                worker: widx as u64,
                            });
                            arrived.insert(slot, (update, p.state));
                        }
                    }
                    // Heartbeats (dispatch acks), stale-round or
                    // stale-session pushes.
                    _ => {}
                },
                Ok(Event::Malformed { conn }) => {
                    // A flaked frame: framing survived, decode did not.
                    // The payload (one update, most likely) is lost; the
                    // affected client stays pending and resolves through
                    // the deadline/migration path like any straggler.
                    self.malformed_frames += 1;
                    let widx = workers.iter().position(|w| w.conn == conn);
                    let who = widx.map(|w| workers[w].name.as_str()).unwrap_or("?");
                    println!(
                        "[serve] round {}: dropped undecodable frame from {who:?}",
                        d.round
                    );
                    self.emit(ObsEvent::Malformed {
                        round: d.round as u64,
                        worker: widx.map(|w| w as u64),
                    });
                }
                Ok(Event::Gone { conn }) => {
                    mark_gone(workers, conn);
                    if let Some(widx) = workers.iter().position(|w| w.conn == conn) {
                        if deadline.is_none() {
                            // No rejoin window without a deadline: cut the
                            // dead worker's pending clients immediately.
                            let _ = book.cut_pending_of(widx);
                        } else if self.opts.migrate {
                            let targets: Vec<usize> = (0..workers.len())
                                .filter(|&w| workers[w].alive)
                                .collect();
                            self.migrate_pending(
                                workers, &mut book, &d, &steps_of, widx, &targets,
                                &mut round_migs,
                            );
                        }
                        // else: leases stay pending — the worker may rejoin
                        // with identity before the deadline cuts them.
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    // With a deadline, the checks at the top of the loop
                    // handle the firing timer. Without one, this IS the
                    // liveness backstop (`ServeOpts::stall_secs`): a round
                    // with no progress is cut instead of wedging the
                    // server forever — announced, never tripped silently.
                    if deadline.is_none() {
                        let pending = book.pending_count();
                        println!(
                            "[serve] round {}: stall backstop ({}s) fired with \
                             {pending} lease(s) pending — cutting",
                            d.round, self.opts.stall_secs
                        );
                        self.emit(ObsEvent::Stall {
                            round: Some(d.round as u64),
                            waited_us: (self.opts.stall_secs * 1e6) as u64,
                            detail: format!(
                                "{pending} lease(s) pending past the liveness backstop"
                            ),
                        });
                        book.cut_all_pending();
                    }
                }
                Err(RecvTimeoutError::Disconnected) => bail!("polling thread died"),
            }
        }

        // Fold arrived updates in slot (= sampled) order; install the
        // advanced client states the workers returned. Cut clients keep
        // their pre-round state — the dropped-client semantics.
        let mut updates: Vec<ClientUpdate> = Vec::with_capacity(arrived.len());
        for (_slot, (update, state)) in arrived {
            self.fed
                .restore_client_state(update.client_id, &state)
                .with_context(|| format!("installing client {} state", update.client_id))?;
            updates.push(update);
        }
        let cut = book.cuts();
        if !cut.is_empty() {
            // A cut lease keeps its pre-round server state, but the worker
            // that held it may have computed and cached the advanced state
            // anyway (deadline-cut straggler, flaked frame). Drop every
            // connection's generation claim for the cut clients so the
            // next assign ships Full, never a Ref into a diverged cache.
            for c in &cut {
                for w in workers.iter_mut() {
                    w.gens.remove(c);
                }
            }
            self.emit(ObsEvent::Cut {
                round: d.round as u64,
                clients: cut.iter().map(|&c| c as u64).collect(),
            });
            self.cuts.push((d.round, cut.clone()));
        }
        if !round_migs.is_empty() {
            self.migrations.push((d.round, round_migs));
        }
        let rec = self.fed.commit_round(d.round, updates, t0)?;
        println!(
            "[serve] round {:>3}  server_ppl {:>9.3}  participated {}/{}  \
             dropped {}  cut {:?}",
            rec.round,
            rec.server_ppl,
            rec.participated,
            self.fed.cfg.clients_per_round,
            d.dropped.len(),
            cut,
        );
        obs::timing("serve", &format!("round {}", rec.round), rec.wall_secs);

        let commit = Msg::RoundCommit(RoundCommit {
            round: rec.round as u64,
            participated: rec.participated as u64,
            global_norm: rec.global_model_norm,
        });
        for w in workers.iter_mut().filter(|w| w.alive) {
            if proto::write_msg(&mut w.stream, &commit, false).is_err() {
                w.alive = false;
            }
        }
        Ok(())
    }

    /// Tree-mode round: lease whole contiguous slices of the sampled
    /// cohort to the connected sub-aggregators and commit from their
    /// pre-folded pushes. No migration — which group folds a client is
    /// part of the tiered-fold math, so leases cannot move between
    /// sub-aggregators without changing the committed bits.
    fn serve_round_tree(
        &mut self,
        rx: &Receiver<Event>,
        workers: &mut Vec<WorkerConn>,
    ) -> Result<()> {
        let t0 = Instant::now();
        self.await_live_worker(rx, workers, self.fed.next_round)?;
        let d = self.fed.plan_round();
        let groups = tier_slices(d.runnable.len(), self.fed.cfg.tiers);

        // A tree round needs one live sub-aggregator per group; wait out
        // the join window for stragglers still connecting or rejoining.
        let give_up =
            Instant::now() + Duration::from_secs_f64(self.opts.join_timeout_secs);
        while workers.iter().filter(|w| w.alive).count() < groups.len() {
            let now = Instant::now();
            if now >= give_up {
                bail!(
                    "tree round {} needs {} sub-aggregator(s), only {} connected \
                     (state is checkpointed; restart with --resume)",
                    d.round,
                    groups.len(),
                    workers.iter().filter(|w| w.alive).count()
                );
            }
            match rx.recv_timeout(give_up - now) {
                Ok(Event::Joined { conn, stream, join, sub }) => {
                    self.admit_or_rejoin(workers, conn, stream, join, sub);
                }
                Ok(Event::Gone { conn }) => mark_gone(workers, conn),
                Ok(_) => {}
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("polling thread died"),
            }
        }
        let live: Vec<usize> =
            (0..workers.len()).filter(|&i| workers[i].alive).collect();

        let mut book = LeaseBook::new(&d.runnable);
        let steps_of: BTreeMap<usize, u64> = d.runnable.iter().copied().collect();
        // Group `gid` is served by sub-aggregator `live[gid]`: the whole
        // slice travels as one RoundAssign (always Full states — the
        // sub-aggregator re-leases them to workers the root knows nothing
        // about) and must come back as one FoldedPush.
        let mut group_of: BTreeMap<usize, usize> = BTreeMap::new();
        for (gid, slice) in groups.iter().enumerate() {
            let widx = live[gid];
            group_of.insert(widx, gid);
            let clients: Vec<usize> =
                d.runnable[slice.clone()].iter().map(|&(c, _)| c).collect();
            for &c in &clients {
                book.lease(c, widx);
                self.emit(ObsEvent::LeaseGrant {
                    round: d.round as u64,
                    client: c as u64,
                    worker: widx as u64,
                });
            }
            self.send_assign(workers, widx, &clients, &d, &steps_of)?;
            if !workers[widx].alive && self.opts.deadline_secs.is_none() {
                // Sub-aggregator unreachable at dispatch and no rejoin
                // window: its whole slice is lost this round (no
                // migration in tree mode).
                let _ = book.cut_pending_of(widx);
            }
        }

        let deadline = self
            .opts
            .deadline_secs
            .map(|s| t0 + Duration::from_secs_f64(s));
        let mut arrived: BTreeMap<usize, (ClientUpdate, ClientCkpt)> = BTreeMap::new();
        // gid -> (carried weight, folded mean) in group order, exactly the
        // second-stage rows `commit_round_folded` verifies and folds.
        let mut folded: BTreeMap<usize, (f64, Vec<f32>)> = BTreeMap::new();
        while book.pending_count() > 0 {
            let now = Instant::now();
            if let Some(dl) = deadline {
                if now >= dl {
                    book.cut_all_pending();
                    break;
                }
            }
            let timeout = match deadline {
                Some(t) => t.saturating_duration_since(now),
                None => Duration::from_secs_f64(self.opts.stall_secs),
            };
            match rx.recv_timeout(timeout) {
                Ok(Event::Joined { conn, stream, join, sub }) => {
                    // A rejoining sub-aggregator reclaims its pending slice
                    // and gets it re-dispatched whole.
                    if let Some(widx) =
                        self.admit_or_rejoin(workers, conn, stream, join, sub)
                    {
                        let reclaimed = book.pending_of(widx);
                        self.send_assign(workers, widx, &reclaimed, &d, &steps_of)?;
                    }
                }
                Ok(Event::Frame { conn, msg }) => match msg {
                    Msg::FoldedPush(fp)
                        if fp.session == self.session && fp.round == d.round as u64 =>
                    {
                        let Some(widx) = workers.iter().position(|w| w.conn == conn)
                        else {
                            continue;
                        };
                        self.accept_folded(
                            workers, &mut book, &d, &group_of, widx, fp, &mut folded,
                            &mut arrived,
                        )?;
                    }
                    // Heartbeats, stale-round/stale-session pushes, and
                    // flat-mode UpdatePushes (invalid in tree mode).
                    _ => {}
                },
                Ok(Event::Malformed { conn }) => {
                    self.malformed_frames += 1;
                    let widx = workers.iter().position(|w| w.conn == conn);
                    let who = widx.map(|w| workers[w].name.as_str()).unwrap_or("?");
                    println!(
                        "[serve] round {}: dropped undecodable frame from {who:?}",
                        d.round
                    );
                    self.emit(ObsEvent::Malformed {
                        round: d.round as u64,
                        worker: widx.map(|w| w as u64),
                    });
                }
                Ok(Event::Gone { conn }) => {
                    mark_gone(workers, conn);
                    if let Some(widx) = workers.iter().position(|w| w.conn == conn) {
                        if deadline.is_none() {
                            let _ = book.cut_pending_of(widx);
                        }
                        // else: the slice stays pending — the sub-aggregator
                        // may rejoin with identity before the deadline.
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    if deadline.is_none() {
                        let pending = book.pending_count();
                        println!(
                            "[serve] round {}: stall backstop ({}s) fired with \
                             {pending} lease(s) pending — cutting",
                            d.round, self.opts.stall_secs
                        );
                        self.emit(ObsEvent::Stall {
                            round: Some(d.round as u64),
                            waited_us: (self.opts.stall_secs * 1e6) as u64,
                            detail: format!(
                                "{pending} lease(s) pending past the liveness backstop"
                            ),
                        });
                        book.cut_all_pending();
                    }
                }
                Err(RecvTimeoutError::Disconnected) => bail!("polling thread died"),
            }
        }

        let mut updates: Vec<ClientUpdate> = Vec::with_capacity(arrived.len());
        for (_slot, (update, state)) in arrived {
            self.fed
                .restore_client_state(update.client_id, &state)
                .with_context(|| format!("installing client {} state", update.client_id))?;
            updates.push(update);
        }
        let cut = book.cuts();
        if !cut.is_empty() {
            // Same generation hygiene as the flat path (tree assigns are
            // always Full today, but the ledger must never claim a cut
            // client's state is held downstream).
            for c in &cut {
                for w in workers.iter_mut() {
                    w.gens.remove(c);
                }
            }
            self.emit(ObsEvent::Cut {
                round: d.round as u64,
                clients: cut.iter().map(|&c| c as u64).collect(),
            });
            self.cuts.push((d.round, cut.clone()));
        }
        let rec = self.fed.commit_round_folded(
            d.round,
            updates,
            folded.into_values().collect(),
            t0,
        )?;
        println!(
            "[serve] round {:>3}  server_ppl {:>9.3}  participated {}/{}  \
             dropped {}  cut {:?}",
            rec.round,
            rec.server_ppl,
            rec.participated,
            self.fed.cfg.clients_per_round,
            d.dropped.len(),
            cut,
        );
        obs::timing("serve", &format!("round {}", rec.round), rec.wall_secs);

        let commit = Msg::RoundCommit(RoundCommit {
            round: rec.round as u64,
            participated: rec.participated as u64,
            global_norm: rec.global_model_norm,
        });
        for w in workers.iter_mut().filter(|w| w.alive) {
            if proto::write_msg(&mut w.stream, &commit, false).is_err() {
                w.alive = false;
            }
        }
        Ok(())
    }

    /// Validate and ledger one FoldedPush. All-or-nothing: the push is the
    /// sub-aggregator's final word on its slice — on any defect the whole
    /// slice is cut through the dropped path, and even on acceptance any
    /// member the sub-aggregator lost downstream (absent from the push)
    /// is cut rather than left pending.
    #[allow(clippy::too_many_arguments)]
    fn accept_folded(
        &mut self,
        workers: &mut [WorkerConn],
        book: &mut LeaseBook,
        d: &RoundDispatch,
        group_of: &BTreeMap<usize, usize>,
        widx: usize,
        fp: FoldedPush,
        folded: &mut BTreeMap<usize, (f64, Vec<f32>)>,
        arrived: &mut BTreeMap<usize, (ClientUpdate, ClientCkpt)>,
    ) -> Result<()> {
        let Some(&gid) = group_of.get(&widx) else {
            // A connection with no leased group this round (late joiner,
            // spare sub-aggregator): nothing to ledger.
            return Ok(());
        };
        if folded.contains_key(&gid) {
            // Duplicate push for an already-committed group: ignore.
            return Ok(());
        }
        // Structural validation. `weight` must be the bit-exact sequential
        // sum of the member sample counts (the weight-carry rule): the
        // root re-derives it at commit, so a sub-aggregator cannot smuggle
        // in a different weighting than its members justify. The members
        // must also arrive duplicate-free and in strictly increasing slot
        // order — exactly the sequence the commit-time verification sums
        // over. A push that duplicates or re-orders members could pass a
        // self-referential weight check here only for the re-derived sum
        // to mismatch at commit and abort the whole run; malformed ⇒ cut,
        // never crash.
        let member_ids: Vec<usize> =
            fp.members.iter().map(|m| m.update.client_id).collect();
        let seq_weight: f64 = fp.members.iter().map(|m| m.update.n_samples).sum();
        let ok = !fp.members.is_empty()
            && fp.mean.len() == self.fed.global.len()
            && fp.weight.to_bits() == seq_weight.to_bits()
            && book.slots_strictly_increasing(&member_ids)
            && fp.members.iter().all(|m| {
                m.update.params.is_empty()
                    && book.owner(m.update.client_id) == Some(widx)
                    && self
                        .fed
                        .check_client_state(m.update.client_id, &m.state)
                        .is_ok()
            });
        if !ok {
            println!(
                "[serve] round {}: rejected folded push from {:?} — cutting its slice",
                d.round, workers[widx].name
            );
            let _ = book.cut_pending_of(widx);
            return Ok(());
        }
        let n_clients = fp.members.len() as u64;
        for m in fp.members {
            let client = m.update.client_id;
            if book.accept(client, widx) {
                let Some(slot) = book.slot(client) else {
                    bail!("lease ledger accepted unsampled client {client}");
                };
                let gen = self.store.put(client, &m.state)?;
                workers[widx].gens.insert(client, gen);
                self.emit(ObsEvent::LeaseFold {
                    round: d.round as u64,
                    client: client as u64,
                    worker: widx as u64,
                });
                arrived.insert(slot, (m.update, m.state));
            }
        }
        // Members the sub-aggregator lost downstream never come back —
        // cut them now instead of waiting out the deadline.
        let _ = book.cut_pending_of(widx);
        self.emit(ObsEvent::FoldedPush {
            round: d.round as u64,
            subagg: widx as u64,
            n_clients,
            weight: fp.weight,
        });
        folded.insert(gid, (fp.weight, fp.mean));
        Ok(())
    }
}

fn mark_gone(workers: &mut [WorkerConn], conn: usize) {
    if let Some(w) = workers.iter_mut().find(|w| w.conn == conn) {
        if w.alive {
            w.alive = false;
            println!("[serve] worker {:?} disconnected", w.name);
        }
    }
}

