//! The deployment-plane Aggregator service: a [`Federation`] whose sampled
//! clients run on remote workers over TCP instead of the in-process round
//! engine (paper §4.1: "Photon offers a fully distributed infrastructure
//! for collaborative pre-training across institutions").
//!
//! ## Equivalence contract
//!
//! The server *is* a `Federation` — same sampler/fault replay
//! ([`Federation::plan_round`]), same streaming aggregation and outer step
//! ([`Federation::commit_round`]), same checkpoints. Workers are stateless
//! executors of [`crate::coordinator::ClientNode::run_local_round`] whose
//! inputs (global model, stream cursors, KeepOpt moments) are shipped per
//! round and whose outputs are folded in sampled order. A localhost fleet therefore reproduces
//! `Federation::run` bit-for-bit: same global model, same round records
//! (modulo wall-clock fields — see `RoundRecord::agrees_with`).
//!
//! ## Faults and elastic membership
//!
//! Every runnable client's round is a **lease** tracked in a
//! [`chaos::LeaseBook`]: dispatched to one worker, folded only from the
//! worker that currently holds it, at most once. On top of that ledger:
//!
//! * A per-round deadline (`ServeOpts::deadline_secs`) cuts stragglers:
//!   when it expires, pending clients drop from the aggregation exactly as
//!   sampler-dropped clients do, and their server-owned state stays at its
//!   pre-round value.
//! * A worker disconnect mid-round cuts its pending clients immediately
//!   when no deadline is configured (the PR 3 behavior). With a deadline,
//!   the leases stay pending until it fires — a **rejoining** worker
//!   (`Join.identity = slot + 1`) reclaims its slot and its in-flight
//!   leases and gets them re-dispatched at their unchanged pre-round
//!   state.
//! * With `ServeOpts::migrate`, leases move instead of waiting: a dead
//!   worker's pending clients are reassigned to live workers right away,
//!   and halfway to the deadline any connected worker that has pushed
//!   nothing has its unstarted clients reassigned too. Stale pushes from
//!   the previous holder are refused by the lease ledger (exactly-once).
//! * A frame that framed correctly but fails link decode (a flake) is
//!   skipped, not fatal: the affected client simply never arrives and is
//!   cut or migrated like any straggler — malformed ⇒ cut, never crash.
//!
//! Every realized cut is recorded in [`Server::cuts`], every realized
//! migration/rejoin next to it; [`Server::trace`] assembles the whole
//! [`chaos::Trace`], and `Federation::run_trace` replays the run
//! bit-exactly in-process. Because the federation checkpoints every
//! round, killing the server and restarting it with the same `--ckpt-dir`
//! resumes sample-exact (`Federation::try_resume_from`) — workers simply
//! reconnect and keep serving.
//!
//! ## Observability
//!
//! With an event sink installed on the federation (`fed.obs`, see the
//! [`crate::obs`] module and docs/OBSERVABILITY.md), the server emits a
//! structured JSONL event per join/rejoin, lease grant/fold, migration,
//! cut, malformed frame, stall, and round commit. Emission sites sit
//! exactly where the server pushes to its own `cuts`/`migrations`/
//! `rejoins` ledgers, so `obs::to_trace(log)` reconstructs
//! [`Server::trace`] bit-for-bit (`tests/props_obs.rs`).

// Wall-clock reads here are transport concerns (deadlines, liveness,
// session ids) — allowlisted; see docs/ANALYSIS.md (nondet-time).
#![allow(clippy::disallowed_methods)]

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::chaos::{self, LeaseBook, Migration};
use crate::ckpt::ClientCkpt;
use crate::coordinator::federation::RoundDispatch;
use crate::coordinator::{ClientUpdate, Federation};
use crate::metrics::RoundRecord;
use crate::net::proto::{
    self, AssignTask, JoinAck, Msg, Reject, RoundAssign, RoundCommit, TaskSpec,
    PROTO_VERSION,
};
use crate::obs::{self, Event as ObsEvent};

/// Deployment-plane service knobs.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Bind address, e.g. `127.0.0.1:7070` (`:0` picks a free port).
    pub bind: String,
    /// Wait for this many workers to join before dispatching round 0.
    pub min_workers: usize,
    /// Per-round straggler deadline in seconds (measured from dispatch);
    /// `None` disables the timer (disconnects still cut — immediately,
    /// since without a deadline there is no bounded rejoin window).
    pub deadline_secs: Option<f64>,
    /// Opt-in mid-round client-lease migration (requires a deadline): a
    /// dead or silent worker's unstarted clients are reassigned to live
    /// workers before the deadline cut. Realized migrations are recorded
    /// in [`Server::migrations`].
    pub migrate: bool,
    /// Deflate model payloads on the wire (lossless; bit-exact decode).
    pub compress: bool,
    /// How long to wait for the admission barrier before giving up.
    pub join_timeout_secs: f64,
    /// Socket write timeout — a worker that stops draining its socket for
    /// this long is declared dead and its pending clients are cut.
    pub io_timeout_secs: f64,
    /// Liveness backstop when no deadline is configured: a round with no
    /// progress for this long is cut (announced with a `Stall` event),
    /// not hung. The default keeps the historical hour.
    pub stall_secs: f64,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            bind: "127.0.0.1:7070".into(),
            min_workers: 1,
            deadline_secs: None,
            migrate: false,
            compress: true,
            join_timeout_secs: 120.0,
            io_timeout_secs: 30.0,
            stall_secs: 3600.0,
        }
    }
}

/// One admitted worker connection (write half; reads happen on a dedicated
/// thread feeding the event channel).
struct WorkerConn {
    conn: usize,
    name: String,
    stream: TcpStream,
    alive: bool,
}

enum Event {
    Joined { conn: usize, stream: TcpStream, join: proto::Join },
    Frame { conn: usize, msg: Msg },
    /// A frame that framed correctly (length prefix intact) but failed
    /// link decode — a flaked payload. The stream itself is still good.
    Malformed { conn: usize },
    Gone { conn: usize },
}

/// The Photon Aggregator as a network service.
pub struct Server {
    fed: Federation,
    opts: ServeOpts,
    listener: Option<TcpListener>,
    addr: SocketAddr,
    session: u64,
    /// Realized deadline/disconnect cuts per round — the schedule that
    /// replays this run in-process via `Federation::run_round_cut`.
    pub cuts: Vec<(usize, Vec<usize>)>,
    /// Realized mid-round client-lease migrations per round (recorded
    /// next to `cuts`; they never affect the math, only who computed).
    pub migrations: Vec<(usize, Vec<Migration>)>,
    /// Realized worker rejoins as `(round, worker_slot)`.
    pub rejoins: Vec<(usize, usize)>,
    /// Flaked (framed-but-undecodable) frames dropped, for diagnostics.
    pub malformed_frames: u64,
}

impl Server {
    /// Bind the service around an existing federation (use
    /// `Federation::new` + `try_resume_from` for the restart path).
    pub fn with_federation(fed: Federation, opts: ServeOpts) -> Result<Server> {
        if opts.migrate {
            anyhow::ensure!(
                opts.deadline_secs.is_some(),
                "--migrate needs a per-round deadline (--deadline-secs) to bound \
                 the migration window"
            );
        }
        anyhow::ensure!(
            opts.stall_secs > 0.0,
            "--stall-secs must be positive (it bounds the no-deadline liveness \
             backstop)"
        );
        let listener = TcpListener::bind(&opts.bind)
            .with_context(|| format!("binding {}", opts.bind))?;
        let addr = listener.local_addr()?;
        let session = fed.cfg.seed
            ^ std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0x5e55_1017);
        Ok(Server {
            fed,
            opts,
            listener: Some(listener),
            addr,
            session,
            cuts: Vec::new(),
            migrations: Vec::new(),
            rejoins: Vec::new(),
            malformed_frames: 0,
        })
    }

    /// The bound address (useful with `bind: "127.0.0.1:0"`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn federation(&self) -> &Federation {
        &self.fed
    }

    pub fn federation_mut(&mut self) -> &mut Federation {
        &mut self.fed
    }

    fn emit(&self, ev: ObsEvent) {
        if let Some(sink) = &self.fed.obs {
            sink.emit(ev);
        }
    }

    /// The realized chaos trace of this run — cuts, migrations, and
    /// rejoins per round, replayable bit-exactly with
    /// `Federation::run_trace`.
    pub fn trace(&self) -> chaos::Trace {
        fn entry(
            rounds: &mut BTreeMap<usize, chaos::RoundTrace>,
            r: usize,
        ) -> &mut chaos::RoundTrace {
            rounds
                .entry(r)
                .or_insert_with(|| chaos::RoundTrace { round: r, ..Default::default() })
        }
        let mut rounds: BTreeMap<usize, chaos::RoundTrace> = BTreeMap::new();
        for (r, c) in &self.cuts {
            entry(&mut rounds, *r).cut = c.clone();
        }
        for (r, m) in &self.migrations {
            entry(&mut rounds, *r).migrations = m.clone();
        }
        for (r, s) in &self.rejoins {
            entry(&mut rounds, *r).rejoined.push(*s);
        }
        chaos::Trace { rounds: rounds.into_values().collect() }
    }

    /// The task spec shipped to joining workers: everything a stateless
    /// worker needs to run local rounds bit-identically.
    fn task_spec(&self) -> TaskSpec {
        let cfg = &self.fed.cfg;
        let islands =
            crate::cluster::island::island_counts(cfg.fleet.as_ref(), cfg.n_clients);
        TaskSpec {
            model: cfg.model.clone(),
            n_params: self.fed.global.len() as u64,
            corpus: cfg.corpus.clone(),
            n_clients: cfg.n_clients as u64,
            seed: cfg.seed,
            schedule: cfg.schedule,
            opt_state: cfg.opt_state,
            islands: islands.iter().map(|&i| i as u32).collect(),
            compress: self.opts.compress,
            codec: cfg.codec,
        }
    }

    /// Admit a fresh worker, or re-attach a returning one to its old slot
    /// (`Join.identity = slot + 1`). Returns `Some(slot)` on a successful
    /// rejoin so the round loop can re-dispatch the reclaimed leases.
    fn admit_or_rejoin(
        &mut self,
        workers: &mut Vec<WorkerConn>,
        conn: usize,
        mut stream: TcpStream,
        join: proto::Join,
    ) -> Option<usize> {
        if join.proto != PROTO_VERSION {
            let reject = Msg::Reject(Reject {
                reason: format!(
                    "worker speaks photon-net v{}, server requires v{PROTO_VERSION}",
                    join.proto
                ),
            });
            let _ = proto::write_msg(&mut stream, &reject, false);
            return None;
        }
        let _ = stream
            .set_write_timeout(Some(Duration::from_secs_f64(self.opts.io_timeout_secs)));
        if join.identity > 0 {
            // Rejoin path: the identity must name a slot this incarnation
            // assigned and that is currently dead — a live slot means the
            // identity is stolen or stale, and an unknown one belongs to a
            // previous server life (state is in the checkpoint, not here).
            let slot = (join.identity - 1) as usize;
            if slot >= workers.len() || workers[slot].alive {
                let reject = Msg::Reject(Reject {
                    reason: format!(
                        "identity {} does not name a reclaimable worker slot",
                        join.identity
                    ),
                });
                let _ = proto::write_msg(&mut stream, &reject, false);
                return None;
            }
            let ack = Msg::JoinAck(JoinAck {
                proto: PROTO_VERSION,
                session: self.session,
                worker_slot: slot as u64,
                spec: self.task_spec(),
            });
            if proto::write_msg(&mut stream, &ack, false).is_err() {
                return None;
            }
            println!(
                "[serve] worker {:?} rejoined slot {slot} (round {})",
                join.name, self.fed.next_round
            );
            workers[slot] = WorkerConn { conn, name: join.name, stream, alive: true };
            self.rejoins.push((self.fed.next_round, slot));
            self.emit(ObsEvent::WorkerRejoin {
                round: self.fed.next_round as u64,
                worker: slot as u64,
                name: workers[slot].name.clone(),
            });
            return Some(slot);
        }
        let ack = Msg::JoinAck(JoinAck {
            proto: PROTO_VERSION,
            session: self.session,
            worker_slot: workers.len() as u64,
            spec: self.task_spec(),
        });
        if proto::write_msg(&mut stream, &ack, false).is_err() {
            return None;
        }
        println!("[serve] admitted worker {:?} (slot {})", join.name, workers.len());
        self.emit(ObsEvent::WorkerJoin {
            worker: workers.len() as u64,
            name: join.name.clone(),
        });
        workers.push(WorkerConn { conn, name: join.name, stream, alive: true });
        None
    }

    /// Serve the whole training run: admit ≥ `min_workers`, dispatch every
    /// remaining round, fold updates, checkpoint, and shut the fleet down.
    /// Returns the complete round-record log (the same shape
    /// `Federation::run` returns).
    pub fn run(&mut self) -> Result<Vec<RoundRecord>> {
        let listener = self
            .listener
            .take()
            .ok_or_else(|| anyhow::anyhow!("Server::run may only be called once"))?;
        let (tx, rx) = mpsc::channel::<Event>();
        let stop = Arc::new(AtomicBool::new(false));
        spawn_acceptor(listener, tx, stop.clone());
        self.emit(ObsEvent::ServerStart {
            session: format!("{:#x}", self.session),
            rounds: self.fed.cfg.rounds as u64,
            n_clients: self.fed.cfg.n_clients as u64,
            clients_per_round: self.fed.cfg.clients_per_round as u64,
        });

        let mut workers: Vec<WorkerConn> = Vec::new();
        let result = self.run_rounds(&rx, &mut workers);

        // Clean shutdown regardless of outcome: tell live workers, then
        // unblock the acceptor so its thread exits.
        for w in workers.iter_mut().filter(|w| w.alive) {
            let _ = proto::write_msg(&mut w.stream, &Msg::Shutdown, false);
        }
        stop.store(true, Ordering::Release);
        let _ = TcpStream::connect(self.addr);
        self.emit(ObsEvent::Shutdown { rounds: self.fed.next_round as u64 });

        result?;
        Ok(self.fed.log.rounds.clone())
    }

    fn run_rounds(
        &mut self,
        rx: &Receiver<Event>,
        workers: &mut Vec<WorkerConn>,
    ) -> Result<()> {
        // Admission barrier.
        let join_deadline =
            Instant::now() + Duration::from_secs_f64(self.opts.join_timeout_secs);
        while workers.iter().filter(|w| w.alive).count() < self.opts.min_workers {
            let now = Instant::now();
            if now >= join_deadline {
                bail!(
                    "timed out waiting for {} workers ({} joined)",
                    self.opts.min_workers,
                    workers.len()
                );
            }
            match rx.recv_timeout(join_deadline - now) {
                Ok(Event::Joined { conn, stream, join }) => {
                    self.admit_or_rejoin(workers, conn, stream, join);
                }
                Ok(Event::Gone { conn }) => mark_gone(workers, conn),
                Ok(Event::Frame { .. }) | Ok(Event::Malformed { .. }) => {}
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("acceptor thread died"),
            }
        }

        while self.fed.next_round < self.fed.cfg.rounds {
            self.serve_round(rx, workers)?;
        }
        Ok(())
    }

    /// Block until at least one worker is alive (a crashed fleet may be
    /// mid-rejoin), up to the join timeout.
    fn await_live_worker(
        &mut self,
        rx: &Receiver<Event>,
        workers: &mut Vec<WorkerConn>,
        round: usize,
    ) -> Result<()> {
        let give_up = Instant::now() + Duration::from_secs_f64(self.opts.join_timeout_secs);
        while !workers.iter().any(|w| w.alive) {
            let now = Instant::now();
            if now >= give_up {
                bail!(
                    "no connected workers left at round {round} (state is \
                     checkpointed; restart with --resume)"
                );
            }
            match rx.recv_timeout(give_up - now) {
                Ok(Event::Joined { conn, stream, join }) => {
                    self.admit_or_rejoin(workers, conn, stream, join);
                }
                Ok(Event::Gone { conn }) => mark_gone(workers, conn),
                Ok(_) => {}
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("acceptor thread died"),
            }
        }
        Ok(())
    }

    /// Re-dispatch `clients` (at their unchanged pre-round state) to
    /// worker `widx` — the rejoin/migration delivery. On a write failure
    /// the worker is marked dead and the leases stay pending for the
    /// deadline (or the next rejoin) to resolve.
    fn send_assign(
        &mut self,
        workers: &mut [WorkerConn],
        widx: usize,
        clients: &[usize],
        d: &RoundDispatch,
        steps_of: &BTreeMap<usize, u64>,
    ) {
        if clients.is_empty() {
            return;
        }
        let tasks: Vec<AssignTask> = clients
            .iter()
            .map(|&c| AssignTask {
                client: c as u64,
                steps: steps_of[&c],
                state: self.fed.client_state(c),
            })
            .collect();
        let msg = Msg::RoundAssign(RoundAssign {
            session: self.session,
            round: d.round as u64,
            seq_base: d.seq_base,
            tasks,
            global: self.fed.global.clone(),
        });
        if proto::write_msg(&mut workers[widx].stream, &msg, self.opts.compress).is_err() {
            workers[widx].alive = false;
        }
    }

    /// Move every pending lease of `from` onto the given live targets and
    /// re-dispatch them. Records the realized migrations.
    fn migrate_pending(
        &mut self,
        workers: &mut [WorkerConn],
        book: &mut LeaseBook,
        d: &RoundDispatch,
        steps_of: &BTreeMap<usize, u64>,
        from: usize,
        targets: &[usize],
        migs: &mut Vec<Migration>,
    ) {
        let moved = book.migrate_from(from, targets);
        if moved.is_empty() {
            return;
        }
        println!(
            "[serve] round {}: migrating {} lease(s) off worker {:?} (slot {from})",
            d.round,
            moved.len(),
            workers[from].name
        );
        for (widx, clients) in LeaseBook::group_by_target(&moved) {
            self.send_assign(workers, widx, &clients, d, steps_of);
        }
        for m in &moved {
            self.emit(ObsEvent::Migration {
                round: d.round as u64,
                client: m.client as u64,
                from: m.from as u64,
                to: m.to as u64,
            });
        }
        migs.extend(moved);
    }

    /// Dispatch, collect, and commit one round.
    fn serve_round(&mut self, rx: &Receiver<Event>, workers: &mut Vec<WorkerConn>) -> Result<()> {
        let t0 = Instant::now();
        self.await_live_worker(rx, workers, self.fed.next_round)?;
        let d = self.fed.plan_round();
        let live: Vec<usize> =
            (0..workers.len()).filter(|&i| workers[i].alive).collect();

        // Static per-round partition of the runnable clients over the live
        // workers, in slot order. Which worker runs a client never affects
        // the math — all state travels with the assignment.
        let mut book = LeaseBook::new(&d.runnable);
        let steps_of: BTreeMap<usize, u64> = d.runnable.iter().copied().collect();
        let mut per_worker: Vec<Vec<usize>> = vec![Vec::new(); workers.len()];
        for (slot, &(client, _)) in d.runnable.iter().enumerate() {
            let widx = live[slot % live.len()];
            book.lease(client, widx);
            self.emit(ObsEvent::LeaseGrant {
                round: d.round as u64,
                client: client as u64,
                worker: widx as u64,
            });
            per_worker[widx].push(client);
        }

        let deadline = self
            .opts
            .deadline_secs
            .map(|s| t0 + Duration::from_secs_f64(s));
        // Opt-in straggler migration fires once, halfway to the deadline.
        let mut migrate_at = match (self.opts.migrate, self.opts.deadline_secs) {
            (true, Some(s)) => Some(t0 + Duration::from_secs_f64(s / 2.0)),
            _ => None,
        };
        let mut round_migs: Vec<Migration> = Vec::new();
        // Progress signal per worker slot: pushes received this round
        // (valid or not) — a worker with leases and zero pushes at the
        // halfway mark is treated as hung and migrated away from.
        // (Keyed, not indexed: workers admitted mid-round grow the list.)
        let mut pushed_by: BTreeMap<usize, u64> = BTreeMap::new();

        for &widx in &live {
            let clients = std::mem::take(&mut per_worker[widx]);
            if clients.is_empty() {
                continue;
            }
            self.send_assign(workers, widx, &clients, &d, &steps_of);
            if !workers[widx].alive && deadline.is_none() {
                // Worker unreachable at dispatch and no rejoin window: cut
                // its share now (the PR 3 semantics).
                let _ = book.cut_pending_of(widx);
            }
        }

        // Collect updates until everyone answered, the deadline fires, or
        // the owning workers die.
        let mut arrived: BTreeMap<usize, (ClientUpdate, ClientCkpt)> = BTreeMap::new();
        while book.pending_count() > 0 {
            let now = Instant::now();
            if let Some(dl) = deadline {
                if now >= dl {
                    book.cut_all_pending();
                    break;
                }
            }
            if let Some(m) = migrate_at {
                if now >= m {
                    migrate_at = None;
                    // Any live worker with leases but no pushes yet is
                    // treated as a silent straggler; its unstarted clients
                    // move to the live workers that are making progress.
                    let silent: Vec<usize> = (0..workers.len())
                        .filter(|&w| {
                            workers[w].alive
                                && pushed_by.get(&w).copied().unwrap_or(0) == 0
                                && !book.pending_of(w).is_empty()
                        })
                        .collect();
                    let targets: Vec<usize> = (0..workers.len())
                        .filter(|&w| workers[w].alive && !silent.contains(&w))
                        .collect();
                    for from in silent {
                        self.migrate_pending(
                            workers, &mut book, &d, &steps_of, from, &targets,
                            &mut round_migs,
                        );
                    }
                    continue;
                }
            }
            // Wait until the next event or the nearest timer.
            let timer = [deadline, migrate_at].into_iter().flatten().min();
            let timeout = match timer {
                Some(t) => t.saturating_duration_since(now),
                // Liveness backstop: with no deadline configured, a round
                // that makes no progress for `stall_secs` is cut, not hung.
                None => Duration::from_secs_f64(self.opts.stall_secs),
            };
            match rx.recv_timeout(timeout) {
                Ok(Event::Joined { conn, stream, join }) => {
                    // Mid-round joins are admitted (work from the next
                    // round on); mid-round REjoins reclaim their pending
                    // leases and get them re-dispatched immediately.
                    if let Some(widx) =
                        self.admit_or_rejoin(workers, conn, stream, join)
                    {
                        let reclaimed = book.pending_of(widx);
                        self.send_assign(workers, widx, &reclaimed, &d, &steps_of);
                    }
                }
                Ok(Event::Frame { conn, msg }) => match msg {
                    Msg::UpdatePush(p)
                        if p.session == self.session && p.round == d.round as u64 =>
                    {
                        let client = p.update.client_id;
                        let Some(widx) = workers.iter().position(|w| w.conn == conn)
                        else {
                            continue;
                        };
                        *pushed_by.entry(widx).or_insert(0) += 1;
                        // Only the current lease holder may answer for a
                        // client — a push from anyone else (rogue peer,
                        // stale reconnect, migrated-away straggler) is
                        // discarded without touching the ledger.
                        if book.owner(client) != Some(widx) {
                            continue;
                        }
                        // Decode-then-fold: rebuild dense params from the
                        // negotiated update codec. The push must match the
                        // negotiation's shape exactly — a dense push where
                        // a coded one was negotiated (or vice versa), a
                        // codec-id mismatch, or any structural defect in
                        // the coded body makes this None.
                        let codec = self.fed.cfg.codec;
                        let mut update = p.update;
                        let reconstructed: Option<u64> = match (codec.is_lossy(), &p.body)
                        {
                            (false, None) => {
                                Some(crate::link::dense_frame_bytes(update.params.len()))
                            }
                            (true, Some(body)) if update.params.is_empty() => {
                                match crate::compress::decode_transit(
                                    &codec,
                                    &self.fed.global,
                                    body,
                                ) {
                                    Ok(params) => {
                                        update.params = params;
                                        Some(crate::link::framed_bytes(body.len()))
                                    }
                                    Err(_) => None,
                                }
                            }
                            _ => None,
                        };
                        let ok = reconstructed.is_some()
                            && update.params.len() == self.fed.global.len()
                            && self.fed.check_client_state(client, &p.state).is_ok();
                        if !ok {
                            // Malformed push from the lease holder: the
                            // update cannot be folded — cut the client
                            // through the dropped path, don't kill the run.
                            book.cut(client);
                            continue;
                        }
                        update.wire_bytes = reconstructed.unwrap_or(0);
                        if book.accept(client, widx) {
                            let Some(slot) = book.slot(client) else {
                                bail!("lease ledger accepted unsampled client {client}");
                            };
                            self.emit(ObsEvent::LeaseFold {
                                round: d.round as u64,
                                client: client as u64,
                                worker: widx as u64,
                            });
                            arrived.insert(slot, (update, p.state));
                        }
                    }
                    // Heartbeats (dispatch acks), stale-round or
                    // stale-session pushes.
                    _ => {}
                },
                Ok(Event::Malformed { conn }) => {
                    // A flaked frame: framing survived, decode did not.
                    // The payload (one update, most likely) is lost; the
                    // affected client stays pending and resolves through
                    // the deadline/migration path like any straggler.
                    self.malformed_frames += 1;
                    let widx = workers.iter().position(|w| w.conn == conn);
                    let who = widx.map(|w| workers[w].name.as_str()).unwrap_or("?");
                    println!(
                        "[serve] round {}: dropped undecodable frame from {who:?}",
                        d.round
                    );
                    self.emit(ObsEvent::Malformed {
                        round: d.round as u64,
                        worker: widx.map(|w| w as u64),
                    });
                }
                Ok(Event::Gone { conn }) => {
                    mark_gone(workers, conn);
                    if let Some(widx) = workers.iter().position(|w| w.conn == conn) {
                        if deadline.is_none() {
                            // No rejoin window without a deadline: cut the
                            // dead worker's pending clients immediately.
                            let _ = book.cut_pending_of(widx);
                        } else if self.opts.migrate {
                            let targets: Vec<usize> = (0..workers.len())
                                .filter(|&w| workers[w].alive)
                                .collect();
                            self.migrate_pending(
                                workers, &mut book, &d, &steps_of, widx, &targets,
                                &mut round_migs,
                            );
                        }
                        // else: leases stay pending — the worker may rejoin
                        // with identity before the deadline cuts them.
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    // With a deadline, the checks at the top of the loop
                    // handle the firing timer. Without one, this IS the
                    // liveness backstop (`ServeOpts::stall_secs`): a round
                    // with no progress is cut instead of wedging the
                    // server forever — announced, never tripped silently.
                    if deadline.is_none() {
                        let pending = book.pending_count();
                        println!(
                            "[serve] round {}: stall backstop ({}s) fired with \
                             {pending} lease(s) pending — cutting",
                            d.round, self.opts.stall_secs
                        );
                        self.emit(ObsEvent::Stall {
                            round: Some(d.round as u64),
                            waited_us: (self.opts.stall_secs * 1e6) as u64,
                            detail: format!(
                                "{pending} lease(s) pending past the liveness backstop"
                            ),
                        });
                        book.cut_all_pending();
                    }
                }
                Err(RecvTimeoutError::Disconnected) => bail!("acceptor thread died"),
            }
        }

        // Fold arrived updates in slot (= sampled) order; install the
        // advanced client states the workers returned. Cut clients keep
        // their pre-round state — the dropped-client semantics.
        let mut updates: Vec<ClientUpdate> = Vec::with_capacity(arrived.len());
        for (_slot, (update, state)) in arrived {
            self.fed
                .restore_client_state(update.client_id, &state)
                .with_context(|| format!("installing client {} state", update.client_id))?;
            updates.push(update);
        }
        let cut = book.cuts();
        if !cut.is_empty() {
            self.emit(ObsEvent::Cut {
                round: d.round as u64,
                clients: cut.iter().map(|&c| c as u64).collect(),
            });
            self.cuts.push((d.round, cut.clone()));
        }
        if !round_migs.is_empty() {
            self.migrations.push((d.round, round_migs));
        }
        let rec = self.fed.commit_round(d.round, updates, t0)?;
        println!(
            "[serve] round {:>3}  server_ppl {:>9.3}  participated {}/{}  \
             dropped {}  cut {:?}",
            rec.round,
            rec.server_ppl,
            rec.participated,
            self.fed.cfg.clients_per_round,
            d.dropped.len(),
            cut,
        );
        obs::timing("serve", &format!("round {}", rec.round), rec.wall_secs);

        let commit = Msg::RoundCommit(RoundCommit {
            round: rec.round as u64,
            participated: rec.participated as u64,
            global_norm: rec.global_model_norm,
        });
        for w in workers.iter_mut().filter(|w| w.alive) {
            if proto::write_msg(&mut w.stream, &commit, false).is_err() {
                w.alive = false;
            }
        }
        Ok(())
    }
}

fn mark_gone(workers: &mut [WorkerConn], conn: usize) {
    if let Some(w) = workers.iter_mut().find(|w| w.conn == conn) {
        if w.alive {
            w.alive = false;
            println!("[serve] worker {:?} disconnected", w.name);
        }
    }
}

/// Accept connections forever (until `stop`); each connection gets a reader
/// thread that performs the Join read and then forwards every frame as an
/// event. Writes stay with the main loop.
fn spawn_acceptor(listener: TcpListener, tx: Sender<Event>, stop: Arc<AtomicBool>) {
    std::thread::spawn(move || {
        let mut next_conn = 0usize;
        for incoming in listener.incoming() {
            if stop.load(Ordering::Acquire) {
                break;
            }
            let stream = match incoming {
                Ok(s) => s,
                Err(_) => continue,
            };
            let conn = next_conn;
            next_conn += 1;
            let tx = tx.clone();
            std::thread::spawn(move || reader_loop(conn, stream, tx));
        }
    });
}

fn reader_loop(conn: usize, stream: TcpStream, tx: Sender<Event>) {
    let mut read = match stream.try_clone() {
        Ok(r) => r,
        Err(_) => return,
    };
    // The first frame must be a Join; anything else is a protocol
    // violation and the connection is silently dropped.
    match proto::read_msg(&mut read) {
        Ok(Msg::Join(join)) => {
            if tx.send(Event::Joined { conn, stream, join }).is_err() {
                return;
            }
        }
        _ => return,
    }
    loop {
        match proto::read_frame(&mut read) {
            // Stream framing intact: a decode failure is a corrupted
            // payload (link flake) — report it and keep reading. Only an
            // IO-level failure means the peer is gone.
            Ok(frame) => {
                let event = match Msg::decode(&frame) {
                    Ok(msg) => Event::Frame { conn, msg },
                    Err(_) => Event::Malformed { conn },
                };
                if tx.send(event).is_err() {
                    return;
                }
            }
            Err(_) => {
                let _ = tx.send(Event::Gone { conn });
                return;
            }
        }
    }
}
