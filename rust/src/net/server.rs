//! The deployment-plane Aggregator service: a [`Federation`] whose sampled
//! clients run on remote workers over TCP instead of the in-process round
//! engine (paper §4.1: "Photon offers a fully distributed infrastructure
//! for collaborative pre-training across institutions").
//!
//! ## Equivalence contract
//!
//! The server *is* a `Federation` — same sampler/fault replay
//! ([`Federation::plan_round`]), same streaming aggregation and outer step
//! ([`Federation::commit_round`]), same checkpoints. Workers are stateless
//! executors of [`crate::coordinator::ClientNode::run_local_round`] whose
//! inputs (global model, stream cursors, KeepOpt moments) are shipped per
//! round and whose outputs are folded in sampled order. A localhost fleet therefore reproduces
//! `Federation::run` bit-for-bit: same global model, same round records
//! (modulo wall-clock fields — see `RoundRecord::agrees_with`).
//!
//! ## Faults
//!
//! A per-round deadline (`ServeOpts::deadline_secs`) cuts stragglers: when
//! it expires, pending clients are dropped from the aggregation exactly as
//! sampler-dropped clients are, and their server-owned state stays at its
//! pre-round value. A worker disconnect mid-round cuts its pending clients
//! immediately through the same path. Every realized cut is recorded in
//! [`Server::cuts`], so the run can be replayed in-process with
//! [`Federation::run_round_cut`]. Because the federation checkpoints every
//! round, killing the server and restarting it with the same `--ckpt-dir`
//! resumes sample-exact (`Federation::try_resume_from`) — workers simply
//! reconnect and keep serving.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::ckpt::ClientCkpt;
use crate::coordinator::{ClientUpdate, Federation};
use crate::metrics::RoundRecord;
use crate::net::proto::{
    self, AssignTask, JoinAck, Msg, Reject, RoundAssign, RoundCommit, TaskSpec,
    PROTO_VERSION,
};

/// Deployment-plane service knobs.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Bind address, e.g. `127.0.0.1:7070` (`:0` picks a free port).
    pub bind: String,
    /// Wait for this many workers to join before dispatching round 0.
    pub min_workers: usize,
    /// Per-round straggler deadline in seconds (measured from dispatch);
    /// `None` disables the timer (disconnects still cut).
    pub deadline_secs: Option<f64>,
    /// Deflate model payloads on the wire (lossless; bit-exact decode).
    pub compress: bool,
    /// How long to wait for the admission barrier before giving up.
    pub join_timeout_secs: f64,
    /// Socket write timeout — a worker that stops draining its socket for
    /// this long is declared dead and its pending clients are cut.
    pub io_timeout_secs: f64,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            bind: "127.0.0.1:7070".into(),
            min_workers: 1,
            deadline_secs: None,
            compress: true,
            join_timeout_secs: 120.0,
            io_timeout_secs: 30.0,
        }
    }
}

/// One admitted worker connection (write half; reads happen on a dedicated
/// thread feeding the event channel).
struct WorkerConn {
    conn: usize,
    name: String,
    stream: TcpStream,
    alive: bool,
}

enum Event {
    Joined { conn: usize, stream: TcpStream, join: proto::Join },
    Frame { conn: usize, msg: Msg },
    Gone { conn: usize },
}

/// The Photon Aggregator as a network service.
pub struct Server {
    fed: Federation,
    opts: ServeOpts,
    listener: Option<TcpListener>,
    addr: SocketAddr,
    session: u64,
    /// Realized deadline/disconnect cuts per round — the schedule that
    /// replays this run in-process via `Federation::run_round_cut`.
    pub cuts: Vec<(usize, Vec<usize>)>,
}

impl Server {
    /// Bind the service around an existing federation (use
    /// `Federation::new` + `try_resume_from` for the restart path).
    pub fn with_federation(fed: Federation, opts: ServeOpts) -> Result<Server> {
        let listener = TcpListener::bind(&opts.bind)
            .with_context(|| format!("binding {}", opts.bind))?;
        let addr = listener.local_addr()?;
        let session = fed.cfg.seed
            ^ std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0x5e55_1017);
        Ok(Server { fed, opts, listener: Some(listener), addr, session, cuts: Vec::new() })
    }

    /// The bound address (useful with `bind: "127.0.0.1:0"`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn federation(&self) -> &Federation {
        &self.fed
    }

    pub fn federation_mut(&mut self) -> &mut Federation {
        &mut self.fed
    }

    /// The task spec shipped to joining workers: everything a stateless
    /// worker needs to run local rounds bit-identically.
    fn task_spec(&self) -> TaskSpec {
        let cfg = &self.fed.cfg;
        let islands =
            crate::cluster::island::island_counts(cfg.fleet.as_ref(), cfg.n_clients);
        TaskSpec {
            model: cfg.model.clone(),
            n_params: self.fed.global.len() as u64,
            corpus: cfg.corpus.clone(),
            n_clients: cfg.n_clients as u64,
            seed: cfg.seed,
            schedule: cfg.schedule,
            opt_state: cfg.opt_state,
            islands: islands.iter().map(|&i| i as u32).collect(),
            compress: self.opts.compress,
            codec: cfg.codec,
        }
    }

    fn admit(&self, workers: &mut Vec<WorkerConn>, conn: usize, mut stream: TcpStream, join: proto::Join) {
        if join.proto != PROTO_VERSION {
            let reject = Msg::Reject(Reject {
                reason: format!(
                    "worker speaks photon-net v{}, server requires v{PROTO_VERSION}",
                    join.proto
                ),
            });
            let _ = proto::write_msg(&mut stream, &reject, false);
            return;
        }
        let _ = stream
            .set_write_timeout(Some(Duration::from_secs_f64(self.opts.io_timeout_secs)));
        let ack = Msg::JoinAck(JoinAck {
            proto: PROTO_VERSION,
            session: self.session,
            worker_slot: workers.len() as u64,
            spec: self.task_spec(),
        });
        if proto::write_msg(&mut stream, &ack, false).is_err() {
            return;
        }
        println!("[serve] admitted worker {:?} (slot {})", join.name, workers.len());
        workers.push(WorkerConn { conn, name: join.name, stream, alive: true });
    }

    /// Serve the whole training run: admit ≥ `min_workers`, dispatch every
    /// remaining round, fold updates, checkpoint, and shut the fleet down.
    /// Returns the complete round-record log (the same shape
    /// `Federation::run` returns).
    pub fn run(&mut self) -> Result<Vec<RoundRecord>> {
        let listener = self
            .listener
            .take()
            .ok_or_else(|| anyhow::anyhow!("Server::run may only be called once"))?;
        let (tx, rx) = mpsc::channel::<Event>();
        let stop = Arc::new(AtomicBool::new(false));
        spawn_acceptor(listener, tx, stop.clone());

        let mut workers: Vec<WorkerConn> = Vec::new();
        let result = self.run_rounds(&rx, &mut workers);

        // Clean shutdown regardless of outcome: tell live workers, then
        // unblock the acceptor so its thread exits.
        for w in workers.iter_mut().filter(|w| w.alive) {
            let _ = proto::write_msg(&mut w.stream, &Msg::Shutdown, false);
        }
        stop.store(true, Ordering::Release);
        let _ = TcpStream::connect(self.addr);

        result?;
        Ok(self.fed.log.rounds.clone())
    }

    fn run_rounds(
        &mut self,
        rx: &Receiver<Event>,
        workers: &mut Vec<WorkerConn>,
    ) -> Result<()> {
        // Admission barrier.
        let join_deadline =
            Instant::now() + Duration::from_secs_f64(self.opts.join_timeout_secs);
        while workers.iter().filter(|w| w.alive).count() < self.opts.min_workers {
            let now = Instant::now();
            if now >= join_deadline {
                bail!(
                    "timed out waiting for {} workers ({} joined)",
                    self.opts.min_workers,
                    workers.len()
                );
            }
            match rx.recv_timeout(join_deadline - now) {
                Ok(Event::Joined { conn, stream, join }) => {
                    self.admit(workers, conn, stream, join)
                }
                Ok(Event::Gone { conn }) => mark_gone(workers, conn),
                Ok(Event::Frame { .. }) => {}
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("acceptor thread died"),
            }
        }

        while self.fed.next_round < self.fed.cfg.rounds {
            self.serve_round(rx, workers)?;
        }
        Ok(())
    }

    /// Dispatch, collect, and commit one round.
    fn serve_round(&mut self, rx: &Receiver<Event>, workers: &mut Vec<WorkerConn>) -> Result<()> {
        let t0 = Instant::now();
        let d = self.fed.plan_round();
        let live: Vec<usize> =
            (0..workers.len()).filter(|&i| workers[i].alive).collect();
        if live.is_empty() {
            bail!(
                "no connected workers left at round {} (state is checkpointed; \
                 restart with --resume)",
                d.round
            );
        }

        // Static per-round partition of the runnable clients over the live
        // workers, in slot order. Which worker runs a client never affects
        // the math — all state travels with the assignment.
        let mut slot_of: HashMap<usize, usize> = HashMap::new();
        let mut owner_of: HashMap<usize, usize> = HashMap::new();
        let mut per_worker: Vec<Vec<AssignTask>> = vec![Vec::new(); workers.len()];
        for (slot, &(client, steps)) in d.runnable.iter().enumerate() {
            let widx = live[slot % live.len()];
            slot_of.insert(client, slot);
            owner_of.insert(client, widx);
            per_worker[widx].push(AssignTask {
                client: client as u64,
                steps,
                state: self.fed.client_state(client),
            });
        }

        let mut pending: BTreeSet<usize> = BTreeSet::new();
        let mut cut: Vec<usize> = Vec::new();
        for widx in live {
            let tasks = std::mem::take(&mut per_worker[widx]);
            if tasks.is_empty() {
                continue;
            }
            let clients: Vec<usize> = tasks.iter().map(|t| t.client as usize).collect();
            let msg = Msg::RoundAssign(RoundAssign {
                session: self.session,
                round: d.round as u64,
                seq_base: d.seq_base,
                tasks,
                global: self.fed.global.clone(),
            });
            match proto::write_msg(&mut workers[widx].stream, &msg, self.opts.compress) {
                Ok(()) => pending.extend(clients),
                Err(_) => {
                    // Worker unreachable at dispatch: cut its share now.
                    workers[widx].alive = false;
                    cut.extend(clients);
                }
            }
        }

        // Collect updates until everyone answered, the deadline fires, or
        // the owning workers die.
        let deadline = self
            .opts
            .deadline_secs
            .map(|s| t0 + Duration::from_secs_f64(s));
        let mut arrived: BTreeMap<usize, (ClientUpdate, ClientCkpt)> = BTreeMap::new();
        while !pending.is_empty() {
            let timeout = match deadline {
                Some(dl) => {
                    let now = Instant::now();
                    if now >= dl {
                        cut.extend(pending.iter().copied());
                        pending.clear();
                        break;
                    }
                    dl - now
                }
                // Liveness backstop: with no deadline configured, a round
                // that makes no progress for an hour is cut, not hung.
                None => Duration::from_secs(3600),
            };
            match rx.recv_timeout(timeout) {
                Ok(Event::Joined { conn, stream, join }) => {
                    // Mid-round joins are admitted and receive work from
                    // the next round on.
                    self.admit(workers, conn, stream, join);
                }
                Ok(Event::Frame { conn, msg }) => match msg {
                    Msg::UpdatePush(p)
                        if p.session == self.session && p.round == d.round as u64 =>
                    {
                        let client = p.update.client_id;
                        // Only the worker the client was assigned to may
                        // answer for it — a push from anyone else (rogue
                        // peer, stale reconnect) is discarded without
                        // touching the pending set.
                        let from = workers.iter().position(|w| w.conn == conn);
                        if from.is_none() || owner_of.get(&client) != from.as_ref() {
                            continue;
                        }
                        // Decode-then-fold: rebuild dense params from the
                        // negotiated update codec. The push must match the
                        // negotiation's shape exactly — a dense push where
                        // a coded one was negotiated (or vice versa), a
                        // codec-id mismatch, or any structural defect in
                        // the coded body makes this None.
                        let codec = self.fed.cfg.codec;
                        let mut update = p.update;
                        let reconstructed: Option<u64> = match (codec.is_lossy(), &p.body)
                        {
                            (false, None) => {
                                Some(crate::link::dense_frame_bytes(update.params.len()))
                            }
                            (true, Some(body)) if update.params.is_empty() => {
                                match crate::compress::decode_transit(
                                    &codec,
                                    &self.fed.global,
                                    body,
                                ) {
                                    Ok(params) => {
                                        update.params = params;
                                        Some(crate::link::framed_bytes(body.len()))
                                    }
                                    Err(_) => None,
                                }
                            }
                            _ => None,
                        };
                        let ok = reconstructed.is_some()
                            && update.params.len() == self.fed.global.len()
                            && self.fed.check_client_state(client, &p.state).is_ok();
                        if !ok {
                            // Malformed push from the owning worker: the
                            // update cannot be folded — cut the client
                            // through the dropped path, don't kill the run.
                            if pending.remove(&client) {
                                cut.push(client);
                            }
                            continue;
                        }
                        update.wire_bytes = reconstructed.unwrap_or(0);
                        if pending.remove(&client) {
                            arrived.insert(slot_of[&client], (update, p.state));
                        }
                    }
                    // Heartbeats (dispatch acks), stale-round or
                    // stale-session pushes.
                    _ => {}
                },
                Ok(Event::Gone { conn }) => {
                    mark_gone(workers, conn);
                    if let Some(widx) = workers.iter().position(|w| w.conn == conn) {
                        let lost: Vec<usize> = pending
                            .iter()
                            .copied()
                            .filter(|c| owner_of.get(c) == Some(&widx))
                            .collect();
                        for c in lost {
                            pending.remove(&c);
                            cut.push(c);
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    cut.extend(pending.iter().copied());
                    pending.clear();
                }
                Err(RecvTimeoutError::Disconnected) => bail!("acceptor thread died"),
            }
        }

        // Fold arrived updates in slot (= sampled) order; install the
        // advanced client states the workers returned. Cut clients keep
        // their pre-round state — the dropped-client semantics.
        let mut updates: Vec<ClientUpdate> = Vec::with_capacity(arrived.len());
        for (_slot, (update, state)) in arrived {
            self.fed
                .restore_client_state(update.client_id, &state)
                .with_context(|| format!("installing client {} state", update.client_id))?;
            updates.push(update);
        }
        cut.sort_unstable();
        if !cut.is_empty() {
            self.cuts.push((d.round, cut.clone()));
        }
        let rec = self.fed.commit_round(d.round, updates, t0)?;
        println!(
            "[serve] round {:>3}  server_ppl {:>9.3}  participated {}/{}  \
             dropped {}  cut {:?}  {:.2}s",
            rec.round,
            rec.server_ppl,
            rec.participated,
            self.fed.cfg.clients_per_round,
            d.dropped.len(),
            cut,
            rec.wall_secs,
        );

        let commit = Msg::RoundCommit(RoundCommit {
            round: rec.round as u64,
            participated: rec.participated as u64,
            global_norm: rec.global_model_norm,
        });
        for w in workers.iter_mut().filter(|w| w.alive) {
            if proto::write_msg(&mut w.stream, &commit, false).is_err() {
                w.alive = false;
            }
        }
        Ok(())
    }
}

fn mark_gone(workers: &mut [WorkerConn], conn: usize) {
    if let Some(w) = workers.iter_mut().find(|w| w.conn == conn) {
        if w.alive {
            w.alive = false;
            println!("[serve] worker {:?} disconnected", w.name);
        }
    }
}

/// Accept connections forever (until `stop`); each connection gets a reader
/// thread that performs the Join read and then forwards every frame as an
/// event. Writes stay with the main loop.
fn spawn_acceptor(listener: TcpListener, tx: Sender<Event>, stop: Arc<AtomicBool>) {
    std::thread::spawn(move || {
        let mut next_conn = 0usize;
        for incoming in listener.incoming() {
            if stop.load(Ordering::Acquire) {
                break;
            }
            let stream = match incoming {
                Ok(s) => s,
                Err(_) => continue,
            };
            let conn = next_conn;
            next_conn += 1;
            let tx = tx.clone();
            std::thread::spawn(move || reader_loop(conn, stream, tx));
        }
    });
}

fn reader_loop(conn: usize, stream: TcpStream, tx: Sender<Event>) {
    let mut read = match stream.try_clone() {
        Ok(r) => r,
        Err(_) => return,
    };
    // The first frame must be a Join; anything else is a protocol
    // violation and the connection is silently dropped.
    match proto::read_msg(&mut read) {
        Ok(Msg::Join(join)) => {
            if tx.send(Event::Joined { conn, stream, join }).is_err() {
                return;
            }
        }
        _ => return,
    }
    loop {
        match proto::read_msg(&mut read) {
            Ok(msg) => {
                if tx.send(Event::Frame { conn, msg }).is_err() {
                    return;
                }
            }
            Err(_) => {
                let _ = tx.send(Event::Gone { conn });
                return;
            }
        }
    }
}
