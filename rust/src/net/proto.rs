//! The deployment plane's control protocol: typed messages the Photon
//! Aggregator (`net::server`) and LLM Node workers (`net::worker`) exchange
//! over TCP, each carried in a Photon-Link frame ([`crate::link`]) with a
//! `u32` length prefix for stream framing.
//!
//! Message flow of one session (paper §4.1 / Algorithm 1):
//!
//! ```text
//! worker                          server
//!   Join {proto, name, id}  ──▶      (id 0 = fresh; slot+1 = rejoin)
//!                           ◀──  JoinAck {session, slot, spec}   (L.1–2)
//!                                   | or Reject {reason}
//!   per round:
//!                           ◀──  RoundAssign {round, tasks, global}  (L.4–6)
//!   Heartbeat {round}       ──▶
//!   UpdatePush {update,st}  ──▶   (one per assigned client, L.7)
//!                           ◀──  RoundCommit {round, participated}   (L.8–11)
//!   at the end:
//!                           ◀──  Shutdown
//! ```
//!
//! Proto v4 adds a tree plane on top of the same flow: a sub-aggregator
//! (`net::subagg`) admits itself with `SubJoin` instead of `Join`, receives
//! the same `RoundAssign` a worker would (its slice of the sampled
//! clients), re-leases those tasks to its own downstream workers, and
//! answers with a single `FoldedPush` — one pre-folded `(weight, mean)`
//! pair plus per-member bookkeeping — where a worker would have sent one
//! `UpdatePush` per client.
//!
//! Workers are **stateless**: every `RoundAssign` task carries the client's
//! full inter-round state ([`ClientCkpt`] — stream cursors + KeepOpt
//! moments) and every `UpdatePush` returns the advanced state. The server
//! owns all state, so a worker cut at the deadline (or a crashed one)
//! leaves its clients exactly at their pre-round state — the same
//! semantics as the sampler's dropped-client path, which is what makes a
//! live run bit-reproducible in-process (`Federation::run_round_cut`).
//!
//! The version handshake is two-layered: the link frame itself rejects
//! newer wire versions, and `Join.proto` / `JoinAck.proto` must equal
//! [`PROTO_VERSION`] or the session is refused with a clear error.
//!
//! The update codec is negotiated once per session: [`TaskSpec::codec`]
//! names the registry entry (`compress::UpdateCodec`), and from then on
//! every `UpdatePush` must match its shape — dense params for the
//! lossless codecs, a coded delta body for the lossy ones. The server
//! treats any mismatch as a malformed push (cut, not crash). The full
//! byte-level spec lives in `docs/PROTOCOL.md`.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::ckpt::{ClientCkpt, Dec, Enc};
use crate::compress::UpdateCodec;
use crate::config::{CorpusKind, OptStatePolicy};
use crate::coordinator::ClientUpdate;
use crate::link::{self, MsgKind};
use crate::optim::schedule::CosineSchedule;

/// Control-protocol version (independent of the link wire version).
/// v2: the task spec negotiates an update codec and `UpdatePush` bodies
/// may carry a lossy-coded pseudo-delta instead of dense params.
/// v3: `Join` carries a rejoin identity — a returning worker reclaims its
/// slot and its in-flight client leases instead of being admitted fresh.
/// v4: multi-tier aggregation — `SubJoin` admits a sub-aggregator peer,
/// `FoldedPush` ships one pre-folded `(weight, mean)` pair plus member
/// bookkeeping upstream, and `AssignTask.state` becomes tagged
/// ([`AssignState`]): `Full` carries the client checkpoint, `Ref` names a
/// generation the worker already holds so idle clients cost 9 bytes.
/// v5: buffered async aggregation — `RoundAssign` carries `lease_epoch`
/// (the server's committed-fold count at dispatch) and `UpdatePush`
/// echoes it back, so the async server can derive an arrival's staleness
/// (`fold_epoch - lease_epoch`) without trusting worker clocks. In async
/// mode the `round` field carries the globally unique grant id (the LR
/// schedule reads `seq_base`, never `round`). Sync/tree paths set
/// `lease_epoch = round` and ignore it on receipt.
pub const PROTO_VERSION: u16 = 5;

/// Refuse to read frames larger than this from a socket (corruption guard;
/// generous enough for a 7B-analogue f32 payload plus KeepOpt moments).
/// Shared with the polling reader (`net::poll`), which applies the same
/// bound to incrementally parsed length prefixes.
pub(crate) const MAX_FRAME_BYTES: usize = 1 << 31;

/// Worker → server: request admission to the federation.
#[derive(Clone, Debug, PartialEq)]
pub struct Join {
    pub proto: u16,
    /// Human-readable worker name (logs only; never an identity).
    pub name: String,
    /// Rejoin identity: `0` requests fresh admission; `slot + 1` asks to
    /// reclaim a previously assigned worker slot (and its in-flight
    /// client leases) after a crash. The server refuses identities that
    /// name a live or unknown slot — an identity is only ever the slot
    /// the *same server incarnation* handed out in its `JoinAck`, so a
    /// worker from a restarted server's past life is rejected cleanly.
    pub identity: u64,
}

/// Everything a stateless worker needs to run local rounds exactly as the
/// in-process federation would: data-plane recipe, schedule, policy, and
/// per-client island arity. Shipped once in [`JoinAck`].
#[derive(Clone, Debug, PartialEq)]
pub struct TaskSpec {
    /// Artifact/model config name the worker must load.
    pub model: String,
    /// Model size sanity check against the worker's loaded artifacts.
    pub n_params: u64,
    pub corpus: CorpusKind,
    pub n_clients: u64,
    pub seed: u64,
    pub schedule: CosineSchedule,
    pub opt_state: OptStatePolicy,
    /// Stream count per client (connectivity islands).
    pub islands: Vec<u32>,
    /// Whether round payloads (model broadcast, update pushes) are
    /// deflate-compressed on the wire.
    pub compress: bool,
    /// Negotiated pseudo-gradient update codec (`compress` registry).
    /// Lossy codecs make every `UpdatePush` ship a coded delta body; the
    /// server decodes-then-folds, so records stay comparable with the
    /// in-process run.
    pub codec: UpdateCodec,
}

/// Server → worker: admission granted.
#[derive(Clone, Debug, PartialEq)]
pub struct JoinAck {
    pub proto: u16,
    /// Session id — changes on server restart; stale pushes are discarded.
    pub session: u64,
    /// Server-assigned worker slot (logs/metrics only).
    pub worker_slot: u64,
    pub spec: TaskSpec,
}

/// The client-state field of an [`AssignTask`]: either the full
/// server-owned checkpoint, or a reference to a state generation the
/// receiving worker provably already holds (it cached the state from a
/// previous assign or from its own push). The server only ever sends
/// `Ref` when its per-connection generation map says the target worker
/// has the current generation; a worker that cannot resolve a `Ref`
/// must bail rather than run from a stale state.
#[derive(Clone, Debug, PartialEq)]
pub enum AssignState {
    /// Full inter-round state (cursors + KeepOpt moments + residual).
    Full(ClientCkpt),
    /// The worker already holds this client's state at this generation.
    Ref(u64),
}

/// One client's work order inside a [`RoundAssign`].
#[derive(Clone, Debug, PartialEq)]
pub struct AssignTask {
    pub client: u64,
    /// Effective local steps after fault injection.
    pub steps: u64,
    /// The client's inter-round state (server-owned), full or by
    /// generation reference (proto v4).
    pub state: AssignState,
}

/// Server → worker: one round's work order plus the global model broadcast.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundAssign {
    pub session: u64,
    /// Round number — or, on the async plane, the globally unique grant
    /// id (proto v5; the LR schedule reads `seq_base`, never this).
    pub round: u64,
    /// Cumulative sequential steps at round start (LR-schedule base).
    pub seq_base: u64,
    /// Server epoch (committed-fold count) at dispatch (proto v5). The
    /// async server derives staleness from its echo; sync paths set it
    /// to the round number and ignore it.
    pub lease_epoch: u64,
    /// This worker's share of the sampled clients, in slot order.
    pub tasks: Vec<AssignTask>,
    pub global: Vec<f32>,
}

/// Worker → server: one client's completed local round.
#[derive(Clone, Debug)]
pub struct UpdatePush {
    pub session: u64,
    /// Round number — or the grant id on the async plane (proto v5).
    pub round: u64,
    /// Echo of the assignment's `lease_epoch` (proto v5) — the async
    /// server's staleness anchor.
    pub lease_epoch: u64,
    /// Metrics + (for the lossless codecs) dense params. When `body` is
    /// `Some`, `update.params` is empty on the wire and the server
    /// reconstructs it by decoding the coded delta against its global
    /// model (decode-then-fold).
    pub update: ClientUpdate,
    /// Lossy-coded pseudo-delta (`compress::UpdateCodec::encode_delta`
    /// output, self-describing codec-id header). `None` ⇔ the negotiated
    /// codec is lossless.
    pub body: Option<Vec<u8>>,
    /// The client's advanced state (cursors + KeepOpt + codec residual)
    /// after the round.
    pub state: ClientCkpt,
}

/// One member client's bookkeeping inside a [`FoldedPush`]: the metrics
/// row (params stripped — the fold already consumed them) plus the
/// client's advanced state, both of which the root still owns.
#[derive(Clone, Debug)]
pub struct FoldedMember {
    /// Per-client metrics. `params` is empty on the wire — the member's
    /// pseudo-gradient only exists inside the sub-aggregator's fold.
    /// Unlike `UpdatePush`, `wire_bytes` IS an explicit wire field here:
    /// the root cannot measure a member's worker→subagg transit itself,
    /// so it trusts the sub-aggregator's measurement. Metric-only — it
    /// never feeds the fold, so a lying subagg can skew a comm counter
    /// but not the model.
    pub update: ClientUpdate,
    /// The member's advanced state after its local round.
    pub state: ClientCkpt,
}

/// Sub-aggregator → root: one leased slice's completed round, pre-folded.
///
/// `mean` is the weighted mean of the slice's arrived member updates in
/// slot order, always dense f32 (never re-coded, whatever codec the
/// worker→subagg leg negotiated). `weight` is the sequential sum of the
/// members' `n_samples` in sampled order — the carry the root needs to
/// fold group means exactly as `vecmath::tiered_fold` does in-process.
#[derive(Clone, Debug)]
pub struct FoldedPush {
    pub session: u64,
    pub round: u64,
    /// Sequential sum of member `n_samples` in sampled order.
    pub weight: f64,
    /// Dense weighted mean of the arrived members' pseudo-gradients.
    pub mean: Vec<f32>,
    /// Per-member metrics + advanced states, in slot (sampled) order.
    /// Members missing from the assigned slice were cut by the subagg.
    pub members: Vec<FoldedMember>,
}

/// Worker → server: assignment acknowledgement, sent on `RoundAssign`
/// receipt. Liveness itself is socket-level — a disconnect cuts the
/// worker's pending clients immediately, and a wedged-but-connected
/// worker is bounded by the per-round deadline.
#[derive(Clone, Debug, PartialEq)]
pub struct Heartbeat {
    pub session: u64,
    pub round: u64,
}

/// Server → worker: the round was folded into the global model.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundCommit {
    pub round: u64,
    /// Clients whose updates made the aggregation (after cuts/drops).
    pub participated: u64,
    pub global_norm: f64,
}

/// Server → worker: admission refused.
#[derive(Clone, Debug, PartialEq)]
pub struct Reject {
    pub reason: String,
}

/// Every message of the deployment-plane control protocol.
#[derive(Clone, Debug)]
pub enum Msg {
    Join(Join),
    JoinAck(JoinAck),
    RoundAssign(RoundAssign),
    UpdatePush(UpdatePush),
    Heartbeat(Heartbeat),
    RoundCommit(RoundCommit),
    Shutdown,
    Reject(Reject),
    /// Sub-aggregator admission request (same body shape as `Join`,
    /// distinct kind so the server can route the peer to the tree plane).
    SubJoin(Join),
    FoldedPush(FoldedPush),
}

fn enc_corpus(e: &mut Enc, c: &CorpusKind) {
    match c {
        CorpusKind::C4Iid => {
            e.u8(0);
            e.u64(0);
        }
        CorpusKind::PileHetero { j } => {
            e.u8(1);
            e.u64(*j as u64);
        }
        CorpusKind::Mc4 { n_langs } => {
            e.u8(2);
            e.u64(*n_langs as u64);
        }
    }
}

fn dec_corpus(d: &mut Dec) -> Result<CorpusKind> {
    let tag = d.u8()?;
    let arg = d.u64()? as usize;
    Ok(match tag {
        0 => CorpusKind::C4Iid,
        1 => CorpusKind::PileHetero { j: arg },
        2 => CorpusKind::Mc4 { n_langs: arg },
        t => bail!("unknown corpus tag {t}"),
    })
}

fn enc_spec(e: &mut Enc, s: &TaskSpec) {
    e.str(&s.model);
    e.u64(s.n_params);
    enc_corpus(e, &s.corpus);
    e.u64(s.n_clients);
    e.u64(s.seed);
    e.f64(s.schedule.eta_max);
    e.f64(s.schedule.alpha);
    e.u64(s.schedule.total_steps);
    e.u64(s.schedule.warmup_steps);
    e.u8(match s.opt_state {
        OptStatePolicy::Stateless => 0,
        OptStatePolicy::KeepOpt => 1,
    });
    e.u64(s.islands.len() as u64);
    for i in &s.islands {
        e.u32(*i);
    }
    e.u8(s.compress as u8);
    let (tag, param) = s.codec.tag_param();
    e.u8(tag);
    e.u32(param);
}

fn dec_spec(d: &mut Dec) -> Result<TaskSpec> {
    let model = d.str()?;
    let n_params = d.u64()?;
    let corpus = dec_corpus(d)?;
    let n_clients = d.u64()?;
    let seed = d.u64()?;
    let schedule = CosineSchedule {
        eta_max: d.f64()?,
        alpha: d.f64()?,
        total_steps: d.u64()?,
        warmup_steps: d.u64()?,
    };
    let opt_state = match d.u8()? {
        0 => OptStatePolicy::Stateless,
        1 => OptStatePolicy::KeepOpt,
        t => bail!("unknown opt-state tag {t}"),
    };
    let n = d.u64()? as usize;
    let mut islands = Vec::with_capacity(d.capacity_hint(n, 4));
    for _ in 0..n {
        islands.push(d.u32()?);
    }
    let compress = d.u8()? != 0;
    let codec = {
        let tag = d.u8()?;
        let param = d.u32()?;
        UpdateCodec::from_tag_param(tag, param)?
    };
    Ok(TaskSpec {
        model,
        n_params,
        corpus,
        n_clients,
        seed,
        schedule,
        opt_state,
        islands,
        compress,
        codec,
    })
}

fn enc_update(e: &mut Enc, u: &ClientUpdate) {
    e.u64(u.client_id as u64);
    e.f64(u.n_samples);
    e.f64(u.loss_mean);
    e.f64(u.loss_last);
    e.f64(u.step_grad_norm_mean);
    e.f64(u.applied_update_norm_mean);
    e.f64(u.act_norm_mean);
    e.f64(u.model_norm);
    e.u64(u.steps_done);
    e.f32s(&u.params);
}

fn dec_update(d: &mut Dec) -> Result<ClientUpdate> {
    Ok(ClientUpdate {
        client_id: d.u64()? as usize,
        n_samples: d.f64()?,
        loss_mean: d.f64()?,
        loss_last: d.f64()?,
        step_grad_norm_mean: d.f64()?,
        applied_update_norm_mean: d.f64()?,
        act_norm_mean: d.f64()?,
        model_norm: d.f64()?,
        steps_done: d.u64()?,
        params: d.f32s()?,
        // Transit size is not a wire field: the receiving server measures
        // it from the frame it actually got (never trusts the sender).
        wire_bytes: 0,
    })
}

fn enc_state(e: &mut Enc, s: &AssignState) {
    match s {
        AssignState::Full(c) => {
            e.u8(0);
            e.client(c);
        }
        AssignState::Ref(gen) => {
            e.u8(1);
            e.u64(*gen);
        }
    }
}

fn dec_state(d: &mut Dec) -> Result<AssignState> {
    Ok(match d.u8()? {
        0 => AssignState::Full(d.client()?),
        1 => AssignState::Ref(d.u64()?),
        t => bail!("unknown assign-state tag {t}"),
    })
}

fn enc_member(e: &mut Enc, m: &FoldedMember) {
    enc_update(e, &m.update);
    // Explicit transit-size carry (see `FoldedMember` docs): the root
    // cannot observe the worker→subagg leg, so the subagg's measurement
    // travels on the wire. Metric-only; never feeds the fold.
    e.u64(m.update.wire_bytes);
    e.client(&m.state);
}

fn dec_member(d: &mut Dec) -> Result<FoldedMember> {
    let mut update = dec_update(d)?;
    update.wire_bytes = d.u64()?;
    let state = d.client()?;
    Ok(FoldedMember { update, state })
}

impl Msg {
    pub fn kind(&self) -> MsgKind {
        match self {
            Msg::Join(_) => MsgKind::Join,
            Msg::JoinAck(_) => MsgKind::JoinAck,
            Msg::RoundAssign(_) => MsgKind::RoundAssign,
            Msg::UpdatePush(_) => MsgKind::UpdatePush,
            Msg::Heartbeat(_) => MsgKind::Heartbeat,
            Msg::RoundCommit(_) => MsgKind::RoundCommit,
            Msg::Shutdown => MsgKind::Shutdown,
            Msg::Reject(_) => MsgKind::Reject,
            Msg::SubJoin(_) => MsgKind::SubJoin,
            Msg::FoldedPush(_) => MsgKind::FoldedPush,
        }
    }

    /// Encode into a Photon-Link frame (compression is only worth it for
    /// the model-bearing kinds; callers pass the session policy).
    pub fn encode(&self, compress: bool) -> Result<Vec<u8>> {
        let mut e = Enc::new();
        match self {
            Msg::Join(m) => {
                e.u16(m.proto);
                e.str(&m.name);
                e.u64(m.identity);
            }
            Msg::JoinAck(m) => {
                e.u16(m.proto);
                e.u64(m.session);
                e.u64(m.worker_slot);
                enc_spec(&mut e, &m.spec);
            }
            Msg::RoundAssign(m) => {
                e.u64(m.session);
                e.u64(m.round);
                e.u64(m.seq_base);
                e.u64(m.lease_epoch);
                e.u64(m.tasks.len() as u64);
                for t in &m.tasks {
                    e.u64(t.client);
                    e.u64(t.steps);
                    enc_state(&mut e, &t.state);
                }
                e.f32s(&m.global);
            }
            Msg::UpdatePush(m) => {
                e.u64(m.session);
                e.u64(m.round);
                e.u64(m.lease_epoch);
                enc_update(&mut e, &m.update);
                e.client(&m.state);
                match &m.body {
                    None => e.u8(0),
                    Some(b) => {
                        e.u8(1);
                        e.bytes(b);
                    }
                }
            }
            Msg::Heartbeat(m) => {
                e.u64(m.session);
                e.u64(m.round);
            }
            Msg::RoundCommit(m) => {
                e.u64(m.round);
                e.u64(m.participated);
                e.f64(m.global_norm);
            }
            Msg::Shutdown => {}
            Msg::Reject(m) => {
                e.str(&m.reason);
            }
            Msg::SubJoin(m) => {
                e.u16(m.proto);
                e.str(&m.name);
                e.u64(m.identity);
            }
            Msg::FoldedPush(m) => {
                e.u64(m.session);
                e.u64(m.round);
                e.f64(m.weight);
                e.f32s(&m.mean);
                e.u64(m.members.len() as u64);
                for mb in &m.members {
                    enc_member(&mut e, mb);
                }
            }
        }
        // Only the model-bearing frames are worth deflating.
        let big = matches!(
            self,
            Msg::RoundAssign(_) | Msg::UpdatePush(_) | Msg::FoldedPush(_)
        );
        link::encode_bytes(self.kind(), &e.buf, compress && big)
    }

    /// Decode a Photon-Link frame into a control message. Borrowing decode:
    /// for uncompressed frames the field reader walks the frame's own body
    /// slice (`link::decode_bytes_ref`), so no per-frame payload copy.
    pub fn decode(frame: &[u8]) -> Result<Msg> {
        let (kind, body) = link::decode_bytes_ref(frame)?;
        let mut d = Dec::new(&body);
        let msg = match kind {
            MsgKind::Join => Msg::Join(Join {
                proto: d.u16()?,
                name: d.str()?,
                identity: d.u64()?,
            }),
            MsgKind::JoinAck => Msg::JoinAck(JoinAck {
                proto: d.u16()?,
                session: d.u64()?,
                worker_slot: d.u64()?,
                spec: dec_spec(&mut d)?,
            }),
            MsgKind::RoundAssign => {
                let session = d.u64()?;
                let round = d.u64()?;
                let seq_base = d.u64()?;
                let lease_epoch = d.u64()?;
                let n = d.u64()? as usize;
                // 25 = minimum encoded AssignTask (ids + tag + state ref).
                let mut tasks = Vec::with_capacity(d.capacity_hint(n, 25));
                for _ in 0..n {
                    tasks.push(AssignTask {
                        client: d.u64()?,
                        steps: d.u64()?,
                        state: dec_state(&mut d)?,
                    });
                }
                let global = d.f32s()?;
                Msg::RoundAssign(RoundAssign {
                    session,
                    round,
                    seq_base,
                    lease_epoch,
                    tasks,
                    global,
                })
            }
            MsgKind::UpdatePush => {
                let session = d.u64()?;
                let round = d.u64()?;
                let lease_epoch = d.u64()?;
                let update = dec_update(&mut d)?;
                let state = d.client()?;
                let body = match d.u8()? {
                    0 => None,
                    1 => Some(d.bytes()?),
                    t => bail!("unknown update-payload tag {t}"),
                };
                Msg::UpdatePush(UpdatePush { session, round, lease_epoch, update, body, state })
            }
            MsgKind::Heartbeat => {
                Msg::Heartbeat(Heartbeat { session: d.u64()?, round: d.u64()? })
            }
            MsgKind::RoundCommit => Msg::RoundCommit(RoundCommit {
                round: d.u64()?,
                participated: d.u64()?,
                global_norm: d.f64()?,
            }),
            MsgKind::Shutdown => Msg::Shutdown,
            MsgKind::Reject => Msg::Reject(Reject { reason: d.str()? }),
            MsgKind::SubJoin => Msg::SubJoin(Join {
                proto: d.u16()?,
                name: d.str()?,
                identity: d.u64()?,
            }),
            MsgKind::FoldedPush => {
                let session = d.u64()?;
                let round = d.u64()?;
                let weight = d.f64()?;
                let mean = d.f32s()?;
                let n = d.u64()? as usize;
                // 105 = minimum encoded FoldedMember (metrics row + empty
                // params + wire_bytes + empty state).
                let mut members = Vec::with_capacity(d.capacity_hint(n, 105));
                for _ in 0..n {
                    members.push(dec_member(&mut d)?);
                }
                Msg::FoldedPush(FoldedPush { session, round, weight, mean, members })
            }
            other => bail!("frame kind {other:?} is not a control message"),
        };
        ensure!(d.done(), "trailing bytes after {:?} body", msg.kind());
        Ok(msg)
    }
}

/// Write one length-prefixed control frame to a stream.
pub fn write_msg(w: &mut impl Write, msg: &Msg, compress: bool) -> Result<()> {
    let frame = msg.encode(compress)?;
    write_frame(w, &frame).with_context(|| format!("writing {:?} frame", msg.kind()))
}

/// Write a pre-encoded link frame with its `u32` length prefix. The chaos
/// harness uses this to ship deliberately corrupted frames with a
/// *consistent* prefix — the stream framing survives, the link decode is
/// what fails, and the receiver can keep reading subsequent frames.
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> Result<()> {
    w.write_all(&(frame.len() as u32).to_le_bytes())
        .and_then(|_| w.write_all(frame))
        .and_then(|_| w.flush())?;
    Ok(())
}

/// Read one length-prefixed frame from a stream (blocking, IO only — no
/// decode). Split from [`read_msg`] so receivers can distinguish a dead
/// stream (IO error here) from a corrupted-but-framed payload (decode
/// error afterwards) and skip the latter instead of dropping the peer.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len).context("reading frame length")?;
    let len = u32::from_le_bytes(len) as usize;
    ensure!(
        (crate::link::HEADER_BYTES..=MAX_FRAME_BYTES).contains(&len),
        "implausible frame length {len}"
    );
    // lint:allow(wire-alloc): len is ensure-bounded to HEADER_BYTES..=MAX_FRAME_BYTES above
    let mut frame = vec![0u8; len];
    r.read_exact(&mut frame).context("reading frame body")?;
    Ok(frame)
}

/// Read one length-prefixed control frame from a stream (blocking).
pub fn read_msg(r: &mut impl Read) -> Result<Msg> {
    Msg::decode(&read_frame(r)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::stream::StreamCursor;

    fn toy_state() -> ClientCkpt {
        ClientCkpt {
            opt_m: vec![0.5, -1.0],
            opt_v: vec![0.25, 4.0],
            local_step: 17,
            cursors: vec![StreamCursor {
                mix_state: [1, 2, 3, 4],
                bucket_states: vec![([5, 6, 7, 8], 9), ([10, 11, 12, 13], 14)],
            }],
            residual: vec![0.125, -2.0],
        }
    }

    fn toy_spec() -> TaskSpec {
        TaskSpec {
            model: "m75a".into(),
            n_params: 123_456,
            corpus: CorpusKind::PileHetero { j: 2 },
            n_clients: 8,
            seed: 42,
            schedule: CosineSchedule {
                eta_max: 3e-3,
                alpha: 0.1,
                total_steps: 2000,
                warmup_steps: 20,
            },
            opt_state: OptStatePolicy::KeepOpt,
            islands: vec![1, 1, 2, 1, 1, 3, 1, 1],
            compress: true,
            codec: UpdateCodec::Q8 { block: 128 },
        }
    }

    fn roundtrip(msg: &Msg, compress: bool) -> Msg {
        Msg::decode(&msg.encode(compress).unwrap()).unwrap()
    }

    #[test]
    fn join_and_ack_roundtrip() {
        for identity in [0u64, 3] {
            let j = Msg::Join(Join {
                proto: PROTO_VERSION,
                name: "worker-3".into(),
                identity,
            });
            match roundtrip(&j, false) {
                Msg::Join(b) => {
                    assert_eq!(b.proto, PROTO_VERSION);
                    assert_eq!(b.name, "worker-3");
                    assert_eq!(b.identity, identity, "rejoin identity survives the wire");
                }
                other => panic!("wrong kind {other:?}"),
            }
        }
        let a = Msg::JoinAck(JoinAck {
            proto: PROTO_VERSION,
            session: 0xDEAD_BEEF,
            worker_slot: 2,
            spec: toy_spec(),
        });
        match roundtrip(&a, false) {
            Msg::JoinAck(b) => {
                assert_eq!(b.session, 0xDEAD_BEEF);
                assert_eq!(b.spec, toy_spec());
            }
            other => panic!("wrong kind {other:?}"),
        }
    }

    #[test]
    fn round_assign_roundtrip_compressed_and_not() {
        let msg = Msg::RoundAssign(RoundAssign {
            session: 7,
            round: 3,
            seq_base: 120,
            lease_epoch: 9,
            tasks: vec![
                AssignTask { client: 1, steps: 40, state: AssignState::Full(toy_state()) },
                AssignTask { client: 5, steps: 20, state: AssignState::Ref(7) },
            ],
            global: (0..300).map(|i| (i as f32 * 0.1).sin()).collect(),
        });
        for compress in [false, true] {
            match roundtrip(&msg, compress) {
                Msg::RoundAssign(b) => {
                    assert_eq!(b.round, 3);
                    assert_eq!(b.lease_epoch, 9, "lease epoch survives the wire (v5)");
                    assert_eq!(b.tasks.len(), 2);
                    assert_eq!(b.tasks[1].client, 5);
                    assert_eq!(b.tasks[0].state, AssignState::Full(toy_state()));
                    assert_eq!(
                        b.tasks[1].state,
                        AssignState::Ref(7),
                        "state reference survives the wire"
                    );
                    assert_eq!(b.global.len(), 300);
                }
                other => panic!("wrong kind {other:?}"),
            }
        }
    }

    #[test]
    fn state_ref_assign_is_much_smaller_than_full() {
        let full = Msg::RoundAssign(RoundAssign {
            session: 1,
            round: 0,
            seq_base: 0,
            lease_epoch: 0,
            tasks: vec![AssignTask {
                client: 1,
                steps: 40,
                state: AssignState::Full(toy_state()),
            }],
            global: Vec::new(),
        });
        let by_ref = Msg::RoundAssign(RoundAssign {
            session: 1,
            round: 0,
            seq_base: 0,
            lease_epoch: 0,
            tasks: vec![AssignTask { client: 1, steps: 40, state: AssignState::Ref(3) }],
            global: Vec::new(),
        });
        let full_len = full.encode(false).unwrap().len();
        let ref_len = by_ref.encode(false).unwrap().len();
        assert!(
            ref_len < full_len,
            "ref assign ({ref_len}B) must undercut full assign ({full_len}B)"
        );
    }

    fn toy_update() -> ClientUpdate {
        ClientUpdate {
            client_id: 6,
            params: vec![1.0, -2.5, 3.25, f32::MIN_POSITIVE],
            n_samples: 160.0,
            loss_mean: 2.3456789,
            loss_last: 2.1,
            step_grad_norm_mean: 0.5,
            applied_update_norm_mean: 0.25,
            act_norm_mean: 12.0,
            model_norm: 99.5,
            steps_done: 40,
            wire_bytes: 0,
        }
    }

    #[test]
    fn update_push_roundtrip_is_bit_exact() {
        let u = toy_update();
        let msg = Msg::UpdatePush(UpdatePush {
            session: 1,
            round: 0,
            lease_epoch: 5,
            update: u.clone(),
            body: None,
            state: toy_state(),
        });
        match roundtrip(&msg, true) {
            Msg::UpdatePush(b) => {
                assert_eq!(b.lease_epoch, 5, "lease-epoch echo survives the wire (v5)");
                assert_eq!(b.update.params, u.params, "f32 payload must be lossless");
                assert_eq!(b.update.n_samples.to_bits(), u.n_samples.to_bits());
                assert_eq!(b.update.loss_mean.to_bits(), u.loss_mean.to_bits());
                assert_eq!(b.state, toy_state());
                assert!(b.body.is_none());
            }
            other => panic!("wrong kind {other:?}"),
        }
    }

    #[test]
    fn coded_update_push_roundtrips_byte_exact() {
        // A lossy-codec push: params empty on the wire, the coded delta
        // travels as an opaque body the server decodes against its global.
        let mut u = toy_update();
        u.params = Vec::new();
        let coded: Vec<u8> = (0..97u8).collect();
        let msg = Msg::UpdatePush(UpdatePush {
            session: 3,
            round: 2,
            lease_epoch: 2,
            update: u,
            body: Some(coded.clone()),
            state: toy_state(),
        });
        for compress in [false, true] {
            match roundtrip(&msg, compress) {
                Msg::UpdatePush(b) => {
                    assert!(b.update.params.is_empty());
                    assert_eq!(b.body.as_deref(), Some(coded.as_slice()));
                    assert_eq!(b.state, toy_state());
                }
                other => panic!("wrong kind {other:?}"),
            }
        }
    }

    #[test]
    fn small_messages_roundtrip() {
        for msg in [
            Msg::Heartbeat(Heartbeat { session: 9, round: 4 }),
            Msg::RoundCommit(RoundCommit { round: 4, participated: 7, global_norm: 3.5 }),
            Msg::Shutdown,
            Msg::Reject(Reject { reason: "proto v2 required".into() }),
        ] {
            let back = roundtrip(&msg, false);
            assert_eq!(back.kind(), msg.kind());
        }
    }

    #[test]
    fn length_prefixed_stream_roundtrip() {
        let mut buf: Vec<u8> = Vec::new();
        write_msg(&mut buf, &Msg::Heartbeat(Heartbeat { session: 1, round: 2 }), false)
            .unwrap();
        write_msg(&mut buf, &Msg::Shutdown, false).unwrap();
        let mut r = &buf[..];
        assert!(matches!(read_msg(&mut r).unwrap(), Msg::Heartbeat(_)));
        assert!(matches!(read_msg(&mut r).unwrap(), Msg::Shutdown));
        assert!(read_msg(&mut r).is_err(), "EOF is an error, not a message");
    }

    #[test]
    fn sub_join_roundtrip_keeps_distinct_kind() {
        let msg = Msg::SubJoin(Join {
            proto: PROTO_VERSION,
            name: "subagg-0".into(),
            identity: 0,
        });
        match roundtrip(&msg, false) {
            Msg::SubJoin(b) => {
                assert_eq!(b.proto, PROTO_VERSION);
                assert_eq!(b.name, "subagg-0");
            }
            other => panic!("SubJoin must not decode as {other:?}"),
        }
        assert_eq!(msg.kind(), MsgKind::SubJoin);
    }

    fn toy_folded() -> FoldedPush {
        let mut u = toy_update();
        u.params = Vec::new();
        u.wire_bytes = 4096;
        FoldedPush {
            session: 11,
            round: 2,
            weight: 320.0,
            mean: vec![0.5, -0.25, f32::MIN_POSITIVE, 3.0],
            members: vec![
                FoldedMember { update: u.clone(), state: toy_state() },
                FoldedMember {
                    update: {
                        let mut v = u;
                        v.client_id = 7;
                        v.wire_bytes = 0;
                        v
                    },
                    state: toy_state(),
                },
            ],
        }
    }

    #[test]
    fn folded_push_roundtrip_is_bit_exact() {
        let fp = toy_folded();
        for compress in [false, true] {
            match roundtrip(&Msg::FoldedPush(fp.clone()), compress) {
                Msg::FoldedPush(b) => {
                    assert_eq!(b.session, fp.session);
                    assert_eq!(b.round, fp.round);
                    assert_eq!(b.weight.to_bits(), fp.weight.to_bits());
                    assert_eq!(b.mean, fp.mean, "folded mean must be lossless");
                    assert_eq!(b.members.len(), 2);
                    assert_eq!(
                        b.members[0].update.wire_bytes, 4096,
                        "member wire_bytes is an explicit wire field in FoldedPush"
                    );
                    assert_eq!(b.members[1].update.client_id, 7);
                    assert_eq!(b.members[0].state, toy_state());
                    assert_eq!(
                        b.members[0].update.n_samples.to_bits(),
                        fp.members[0].update.n_samples.to_bits()
                    );
                }
                other => panic!("wrong kind {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_folded_push_is_rejected() {
        let frame = Msg::FoldedPush(toy_folded()).encode(false).unwrap();
        // Chop inside the member list: decode must error, never invent
        // members or mis-decode as a different message.
        for cut in [frame.len() - 1, frame.len() - 40, crate::link::HEADER_BYTES + 4] {
            assert!(Msg::decode(&frame[..cut]).is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn model_payload_frames_are_not_control_messages() {
        let f = crate::link::encode_model(MsgKind::GlobalModel, &[1.0, 2.0], false).unwrap();
        assert!(Msg::decode(&f).is_err());
    }

    #[test]
    fn implausible_length_prefix_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(&[0u8; 64]);
        let mut r = &buf[..];
        let err = read_msg(&mut r).unwrap_err().to_string();
        assert!(err.contains("implausible"), "{err}");
    }
}
