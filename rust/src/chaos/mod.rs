//! Seeded chaos injection for the elastic deployment plane (paper §5:
//! federated pre-training is "highly resilient to the classical challenges
//! of federated statistical and hardware heterogeneity" and "robust to
//! partial participation"; Photon, arXiv:2411.02908: stateless LLM Nodes
//! crash, rejoin, and migrate work without derailing the run).
//!
//! The subsystem has four pieces, all deterministic from one seed:
//!
//! * [`Schedule`] — a seed-derived fault plan, one [`Fault`] per
//!   (worker, round): crash (with optional rejoin-after-delay),
//!   hang-past-deadline, slow-down factor, or a link flake that corrupts
//!   one wire frame. `net::harness::run_loopback` injects it into the
//!   worker threads; [`Schedule::apply_to_plan`] prices the same churn
//!   into a [`sim`](crate::sim) round plan.
//! * [`flake_frame`] — deterministic corruption of a Photon-Link frame
//!   (payload bit flip, checksum flip, or truncation). A flaked frame is
//!   *rejected* by the link decoder, never mis-decoded — property-tested
//!   in `tests/props_chaos.rs`.
//! * [`LeaseBook`] — the per-round client-lease ledger `net::server`
//!   dispatches through: who owns each runnable client, who arrived, who
//!   was cut. It enforces **exactly-once client execution per round**
//!   (a push folds only from the current lease holder, and only once),
//!   which is what keeps mid-round lease migration and worker rejoin
//!   bit-compatible with the dropped-client path.
//! * [`Trace`] — the *realized* outcome of a chaotic run (cuts, lease
//!   migrations, rejoins per round), assembled by `net::Server::trace`.
//!   `Federation::run_round_trace` replays it in-process: since worker
//!   identity never affects the math, the replay reduces to the cut
//!   schedule, and a chaotic TCP run stays bit-equal to its replay.
//!
//! ## Determinism
//!
//! Every fault cell is derived per (seed, worker, round) exactly like
//! [`crate::cluster::faults::FaultPlan`] derives client faults, so the
//! *schedule* is reproducible — extending a schedule to more rounds or
//! workers never changes existing cells. The *realization* (which clients
//! actually get cut under real scheduling jitter) is captured in the
//! [`Trace`], and the parity contract is on the trace: any realization
//! replays bit-exactly.

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use crate::sim::{Participant, RoundPlan, RoundSpec};
use crate::util::rng::Rng;

/// Domain-separation tag so chaos draws never correlate with the client
/// [`FaultPlan`](crate::cluster::faults::FaultPlan) draws sharing a seed.
const CHAOS_TAG: u64 = 0xC8A0_5EED_0F1E_E75C;

/// One worker's misbehavior in one round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Behave normally.
    None,
    /// Disconnect on receiving the round's assignment, before replying.
    /// `rejoin_after_ms` brings the worker back (with its identity) after
    /// a delay; `None` means gone for good.
    Crash { rejoin_after_ms: Option<u64> },
    /// Stay connected but sit the round out: acknowledge the assignment,
    /// never push an update. The server's deadline (or lease migration)
    /// handles the silence.
    Hang,
    /// Serve the round `factor`× slower (a sleep before every push) —
    /// exercises late arrivals and the straggler-migration path.
    Slow { factor: f64 },
    /// Corrupt the wire frame of one `UpdatePush` (the `victim`-th task
    /// of the assignment, modulo its length) via [`flake_frame`] with
    /// `seed`. The server must reject the frame, never mis-decode it;
    /// the affected client is cut at the deadline like any straggler.
    Flake { victim: u32, seed: u64 },
}

/// Per-kind fault probabilities for [`Schedule::generate`]. The draws are
/// mutually exclusive per cell (one fault at most), evaluated in the
/// order crash → hang → slow → flake.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosConfig {
    pub crash_prob: f64,
    pub hang_prob: f64,
    pub slow_prob: f64,
    pub flake_prob: f64,
    /// P(a crashed worker rejoins with its identity after a delay).
    pub rejoin_prob: f64,
    /// Upper bound on the rejoin delay (drawn uniformly in
    /// `[rejoin_delay_ms/2, rejoin_delay_ms]`).
    pub rejoin_delay_ms: u64,
    /// Upper bound on the slow-down factor (drawn in `[1, slow_factor]`).
    pub slow_factor: f64,
    /// Never crash or hang worker 0, so every round keeps at least one
    /// live executor and the run always terminates. Slow-downs and flakes
    /// still apply to it.
    pub protect_worker0: bool,
}

impl ChaosConfig {
    /// A quiet fleet (every cell draws [`Fault::None`]).
    pub fn none() -> ChaosConfig {
        ChaosConfig {
            crash_prob: 0.0,
            hang_prob: 0.0,
            slow_prob: 0.0,
            flake_prob: 0.0,
            rejoin_prob: 0.0,
            rejoin_delay_ms: 40,
            slow_factor: 3.0,
            protect_worker0: true,
        }
    }

    /// Split an aggregate per-cell fault rate across the four kinds with
    /// the default mix (crash-heavy, as in the paper's dropout framing).
    pub fn at_rate(rate: f64) -> ChaosConfig {
        ChaosConfig {
            crash_prob: rate * 0.35,
            hang_prob: rate * 0.25,
            slow_prob: rate * 0.20,
            flake_prob: rate * 0.20,
            rejoin_prob: 0.75,
            ..ChaosConfig::none()
        }
    }

    /// Total per-cell fault probability.
    pub fn total_rate(&self) -> f64 {
        self.crash_prob + self.hang_prob + self.slow_prob + self.flake_prob
    }
}

/// A deterministic, seed-derived fault plan over `workers × rounds`.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    pub seed: u64,
    pub workers: usize,
    pub rounds: usize,
    pub cfg: ChaosConfig,
}

impl Schedule {
    pub fn generate(seed: u64, workers: usize, rounds: usize, cfg: ChaosConfig) -> Schedule {
        Schedule { seed, workers, rounds, cfg }
    }

    /// The fault of one (worker, round) cell. Derived per cell — never
    /// from shared RNG state — so cells are independent of the schedule's
    /// extent and of each other.
    pub fn fault(&self, worker: usize, round: usize) -> Fault {
        if round >= self.rounds || worker >= self.workers {
            return Fault::None;
        }
        let mut rng = Rng::new(
            self.seed
                ^ CHAOS_TAG
                ^ (round as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ ((worker as u64).wrapping_add(1)).wrapping_mul(0xD1B54A32D192ED03),
        );
        let x = rng.f64();
        let c = &self.cfg;
        let fault = if x < c.crash_prob {
            let rejoin = rng.bool(c.rejoin_prob);
            let half = (c.rejoin_delay_ms / 2).max(1);
            let delay = half + rng.below(c.rejoin_delay_ms.saturating_sub(half).max(1));
            Fault::Crash { rejoin_after_ms: rejoin.then_some(delay) }
        } else if x < c.crash_prob + c.hang_prob {
            Fault::Hang
        } else if x < c.crash_prob + c.hang_prob + c.slow_prob {
            Fault::Slow { factor: 1.0 + rng.f64() * (c.slow_factor - 1.0).max(0.0) }
        } else if x < c.total_rate() {
            Fault::Flake { victim: rng.below(1 << 16) as u32, seed: rng.next_u64() }
        } else {
            Fault::None
        };
        if c.protect_worker0
            && worker == 0
            && matches!(fault, Fault::Crash { .. } | Fault::Hang)
        {
            return Fault::None;
        }
        fault
    }

    /// One worker's view of the plan, ready to move into its thread.
    pub fn worker(&self, worker: usize) -> WorkerChaos {
        WorkerChaos {
            worker,
            faults: (0..self.rounds).map(|r| self.fault(worker, r)).collect(),
        }
    }

    /// True when any cell hangs or flakes — those faults leave clients
    /// pending on a live connection, so the fleet needs a per-round
    /// deadline to cut them (crashes alone cut on disconnect).
    pub fn needs_deadline(&self) -> bool {
        (0..self.rounds).any(|r| {
            (0..self.workers).any(|w| {
                matches!(self.fault(w, r), Fault::Hang | Fault::Flake { .. })
            })
        })
    }

    /// True when every cell is [`Fault::None`].
    pub fn is_quiet(&self) -> bool {
        (0..self.rounds)
            .all(|r| (0..self.workers).all(|w| self.fault(w, r) == Fault::None))
    }

    /// Price this schedule's churn into a simulator round plan, mirroring
    /// the server's dispatch rule (sampled slot s → s-th live worker,
    /// round-robin): clients of crashed/hung workers drop (or survive via
    /// lease migration when `migrate`), flake victims drop, clients of
    /// slowed workers straggle. Crashed workers with a rejoin delay miss
    /// only the crash round; without one they stay dead. This is the
    /// *pricing* model for `photon exp chaos` wall-clock estimates — the
    /// bit-parity contract lives in [`Trace`], not here.
    pub fn apply_to_plan(&self, plan: &RoundPlan, migrate: bool) -> RoundPlan {
        let mut live = vec![true; self.workers.max(1)];
        let mut rejoin_at: Vec<Option<usize>> = vec![None; self.workers.max(1)];
        let mut rounds = Vec::with_capacity(plan.rounds.len());
        for spec in &plan.rounds {
            let r = spec.round;
            for w in 0..live.len() {
                if rejoin_at[w] == Some(r) {
                    live[w] = true;
                    rejoin_at[w] = None;
                }
            }
            let live_idx: Vec<usize> = (0..live.len()).filter(|&w| live[w]).collect();
            let mut participants = Vec::new();
            let mut dropped = spec.dropped.clone();
            if live_idx.is_empty() {
                dropped.extend(spec.participants.iter().map(|p| p.client));
                rounds.push(RoundSpec { round: r, participants, dropped });
                continue;
            }
            // Per-worker task lists in dispatch order (for flake victims).
            let mut task_of: Vec<Vec<usize>> = vec![Vec::new(); live.len()];
            for (slot, _) in spec.participants.iter().enumerate() {
                task_of[live_idx[slot % live_idx.len()]].push(slot);
            }
            for (slot, p) in spec.participants.iter().enumerate() {
                let w = live_idx[slot % live_idx.len()];
                match self.fault(w, r) {
                    Fault::Crash { .. } | Fault::Hang if !migrate => {
                        dropped.push(p.client)
                    }
                    Fault::Flake { victim, .. }
                        if task_of[w][victim as usize % task_of[w].len()] == slot =>
                    {
                        dropped.push(p.client)
                    }
                    Fault::Slow { .. } => {
                        participants.push(Participant { straggler: true, ..p.clone() })
                    }
                    _ => participants.push(p.clone()),
                }
            }
            for &w in &live_idx {
                if let Fault::Crash { rejoin_after_ms } = self.fault(w, r) {
                    live[w] = false;
                    if rejoin_after_ms.is_some() {
                        rejoin_at[w] = Some(r + 1);
                    }
                }
            }
            rounds.push(RoundSpec { round: r, participants, dropped });
        }
        RoundPlan { n_clients: plan.n_clients, tau: plan.tau, rounds }
    }
}

/// One worker's slice of a [`Schedule`], movable into its thread.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerChaos {
    pub worker: usize,
    faults: Vec<Fault>,
}

impl WorkerChaos {
    pub fn fault(&self, round: u64) -> Fault {
        self.faults
            .get(round as usize)
            .copied()
            .unwrap_or(Fault::None)
    }

    /// Clear one round's fault — the harness consumes a crash before the
    /// worker rejoins, so the re-dispatched round does not crash it again
    /// in a loop.
    pub fn consume(&mut self, round: u64) {
        if let Some(f) = self.faults.get_mut(round as usize) {
            *f = Fault::None;
        }
    }
}

/// Deterministically corrupt a Photon-Link frame so its decode **fails**
/// (the link checksum/length/flag validation rejects it). Variants:
/// payload bit flip, truncation, or checksum-only damage — and *every*
/// variant also flips one bit of the stored FNV-1a checksum, so the frame
/// can never checksum-match whatever payload the decoder reconstructs.
/// (A lone payload flip could land in deflate padding bits and inflate
/// back to the original bytes; the unconditional checksum flip closes
/// that hole — a flaked frame is rejected, never silently mis-decoded.)
pub fn flake_frame(frame: &mut Vec<u8>, seed: u64) {
    let hdr = crate::link::HEADER_BYTES;
    let mut rng = Rng::new(seed ^ 0xF1A4_EF1A_4EF1_A4EF);
    if frame.len() < hdr {
        // Already unframeable; shorten it further for variety.
        frame.truncate(frame.len() / 2);
        return;
    }
    let variant = rng.below(3);
    if variant == 2 && frame.len() > hdr + 1 {
        // Truncate somewhere inside the payload...
        let keep = hdr + rng.usize_below(frame.len() - hdr);
        frame.truncate(keep.max(hdr));
    } else if variant == 1 && frame.len() > hdr {
        // ...or flip one payload bit...
        let i = hdr + rng.usize_below(frame.len() - hdr);
        frame[i] ^= 1 << rng.below(8);
    }
    // ...and always defeat the integrity check: flip one bit of the
    // stored checksum (bytes 20..28). The odds of the damaged payload
    // FNV-hashing onto the damaged checksum are 2⁻⁶⁴.
    let i = 20 + rng.usize_below(8);
    frame[i] ^= 1 << rng.below(8);
}

/// One realized client-lease migration: `client`'s lease moved from
/// worker slot `from` to slot `to` mid-round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Migration {
    pub client: usize,
    pub from: usize,
    pub to: usize,
}

/// The realized fate of one round of a chaotic deployment-plane run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundTrace {
    pub round: usize,
    /// Clients cut from the aggregation (deadline, disconnect, malformed
    /// push) — the only field that affects the replayed math.
    pub cut: Vec<usize>,
    /// Leases migrated to live workers before the deadline.
    pub migrations: Vec<Migration>,
    /// Worker slots that rejoined with identity during the round.
    pub rejoined: Vec<usize>,
}

/// The realized trace of a whole run (sparse: only eventful rounds).
/// Assembled by `net::Server::trace`, replayed by
/// `Federation::run_trace` — the two must agree bit-for-bit on records
/// and the final global model (the ISSUE 5 acceptance invariant).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub rounds: Vec<RoundTrace>,
}

impl Trace {
    pub fn for_round(&self, round: usize) -> Option<&RoundTrace> {
        self.rounds.iter().find(|t| t.round == round)
    }

    /// The cut schedule of one round (empty when the round was clean).
    pub fn cut_for(&self, round: usize) -> &[usize] {
        self.for_round(round).map(|t| t.cut.as_slice()).unwrap_or(&[])
    }

    pub fn total_cut(&self) -> usize {
        self.rounds.iter().map(|t| t.cut.len()).sum()
    }

    pub fn total_migrated(&self) -> usize {
        self.rounds.iter().map(|t| t.migrations.len()).sum()
    }

    pub fn total_rejoined(&self) -> usize {
        self.rounds.iter().map(|t| t.rejoined.len()).sum()
    }

    pub fn is_quiet(&self) -> bool {
        self.rounds.is_empty()
    }
}

/// Staleness-discounted, normalized fold weights for one buffered async
/// aggregation (FedBuff-style, arXiv:2409.15723 §4): each arrival's base
/// weight `w_i` (its `n_samples`) is discounted by `gamma^staleness_i`
/// and the discounted weights are normalized to sum to 1.
///
/// This is THE weight function of the async plane: `net::server` calls it
/// when a fold closes, records the outputs in the [`AsyncTrace`], and
/// `Federation::commit_async_fold` re-derives them from the raw
/// `(n_samples, staleness)` pairs at commit and verifies the recorded
/// weights **bitwise** (the PR 9 weight-carry rule) — so fleet and replay
/// can only ever fold with identical coefficients. Pure sequential f64 in
/// input order; callers pass arrivals in canonical (ascending grant)
/// order.
///
/// `gamma` ∈ (0, 1]: 1 disables the discount (pure sample weighting),
/// smaller values bias the fold toward fresher updates. With all base
/// weights positive the outputs are positive, sum to 1, and are monotone
/// non-increasing in staleness for equal base weights — property-tested
/// in `tests/props_async.rs`.
pub fn discounted_weights(base: &[f64], staleness: &[u64], gamma: f64) -> Vec<f64> {
    debug_assert_eq!(base.len(), staleness.len());
    debug_assert!(gamma > 0.0 && gamma <= 1.0, "gamma {gamma} outside (0,1]");
    let d: Vec<f64> = base
        .iter()
        .zip(staleness)
        .map(|(&w, &s)| w * gamma.powi(s.min(i32::MAX as u64) as i32))
        .collect();
    let total: f64 = d.iter().sum();
    d.iter().map(|&x| x / total).collect()
}

/// One work grant of the async plane: a single-client lease dispatched by
/// the buffered-async server. The grant id is globally unique and
/// monotone in dispatch order — it travels as the `round` field of the
/// `RoundAssign`/`UpdatePush` pair (the LR schedule reads `seq_base`, not
/// `round`, so the field is free to carry it), keys the transit codec's
/// dither seed, and defines the **canonical fold order**: a closing fold
/// sorts its buffered arrivals by ascending grant id before folding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AsyncGrant {
    /// Globally unique, dispatch-ordered grant id.
    pub grant: u64,
    pub client: usize,
    /// Local steps the client runs under this grant.
    pub steps: u64,
    /// Server epoch (= committed folds = global-model version) at
    /// dispatch. Staleness at fold time is `fold_epoch - born_epoch`.
    pub born_epoch: u64,
    /// Cumulative sequential steps at dispatch (LR-schedule base) —
    /// recorded explicitly so replay is a pure function of the trace.
    pub seq_base: u64,
}

/// One arrival inside a committed [`AsyncFold`], in canonical (ascending
/// grant) order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AsyncArrival {
    pub grant: u64,
    pub client: usize,
    /// `fold_epoch - born_epoch` (0 = folded against the same global it
    /// was computed from).
    pub staleness: u64,
    /// The normalized staleness-discounted fold weight
    /// ([`discounted_weights`] output) — re-derived and verified bitwise
    /// at commit.
    pub weight: f64,
}

/// One committed buffered fold: the K arrivals that closed epoch
/// `epoch` (producing global-model version `epoch + 1`).
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncFold {
    pub epoch: u64,
    /// Arrivals in canonical (ascending grant) order.
    pub arrivals: Vec<AsyncArrival>,
}

/// The realized outcome of a buffered-async run: every grant dispatched,
/// every fold committed, every grant cut (crash, malformed push, per-grant
/// deadline, or still in flight at shutdown). Assembled by
/// `net::Server`, replayed bit-exactly by `Federation::run_async_trace`
/// — the async analogue of [`Trace`].
///
/// Exactly-once accounting: every grant id appears in **exactly one**
/// fold's arrivals or in `cut`, never both, never twice
/// ([`AsyncTrace::check_exactly_once`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AsyncTrace {
    /// Buffer size: a fold closes at exactly `k` arrivals.
    pub k: usize,
    /// Staleness discount base (∈ (0, 1]).
    pub gamma: f64,
    /// Every grant dispatched, ascending by grant id.
    pub grants: Vec<AsyncGrant>,
    /// Committed folds, ascending by epoch (one per epoch, consecutive
    /// from the run's first epoch).
    pub folds: Vec<AsyncFold>,
    /// Grant ids that never folded, ascending.
    pub cut: Vec<u64>,
}

impl AsyncTrace {
    pub fn grant(&self, id: u64) -> Option<&AsyncGrant> {
        self.grants.iter().find(|g| g.grant == id)
    }

    /// Grant ids folded across all epochs.
    pub fn total_folded(&self) -> usize {
        self.folds.iter().map(|f| f.arrivals.len()).sum()
    }

    pub fn total_cut(&self) -> usize {
        self.cut.len()
    }

    /// Largest realized staleness across all folds (0 on an empty trace).
    pub fn staleness_max(&self) -> u64 {
        self.folds
            .iter()
            .flat_map(|f| f.arrivals.iter().map(|a| a.staleness))
            .max()
            .unwrap_or(0)
    }

    /// Mean realized staleness across all folded arrivals.
    pub fn staleness_mean(&self) -> f64 {
        let n = self.total_folded();
        if n == 0 {
            return 0.0;
        }
        let sum: u64 = self
            .folds
            .iter()
            .flat_map(|f| f.arrivals.iter().map(|a| a.staleness))
            .sum();
        sum as f64 / n as f64
    }

    /// Structural invariants: every dispatched grant resolves exactly once
    /// (one fold membership XOR one cut), folds reference only dispatched
    /// grants, arrivals are in canonical order with consistent staleness,
    /// and epochs are consecutive.
    pub fn check_exactly_once(&self) -> Result<(), String> {
        let mut resolved: BTreeSet<u64> = BTreeSet::new();
        let by_id: BTreeMap<u64, &AsyncGrant> =
            self.grants.iter().map(|g| (g.grant, g)).collect();
        if by_id.len() != self.grants.len() {
            return Err("duplicate grant id in grants".into());
        }
        for (i, f) in self.folds.iter().enumerate() {
            if f.epoch != self.folds[0].epoch + i as u64 {
                return Err(format!("fold epochs not consecutive at index {i}"));
            }
            let mut prev: Option<u64> = None;
            for a in &f.arrivals {
                let Some(g) = by_id.get(&a.grant) else {
                    return Err(format!("fold {} references unknown grant {}", f.epoch, a.grant));
                };
                if g.client != a.client {
                    return Err(format!("grant {} client mismatch in fold", a.grant));
                }
                if g.born_epoch + a.staleness != f.epoch {
                    return Err(format!(
                        "grant {} staleness {} inconsistent with born epoch {} at fold {}",
                        a.grant, a.staleness, g.born_epoch, f.epoch
                    ));
                }
                if prev.is_some_and(|p| p >= a.grant) {
                    return Err(format!("fold {} arrivals not in canonical order", f.epoch));
                }
                prev = Some(a.grant);
                if !resolved.insert(a.grant) {
                    return Err(format!("grant {} resolved twice", a.grant));
                }
            }
        }
        for &c in &self.cut {
            if !by_id.contains_key(&c) {
                return Err(format!("cut references unknown grant {c}"));
            }
            if !resolved.insert(c) {
                return Err(format!("grant {c} resolved twice (fold + cut)"));
            }
        }
        for g in &self.grants {
            if !resolved.contains(&g.grant) {
                return Err(format!("grant {} dispatched but never resolved", g.grant));
            }
        }
        Ok(())
    }
}

/// The async plane's grant ledger: which worker owns each in-flight
/// grant, which client each grant runs, who arrived, who was cut. The
/// async analogue of [`LeaseBook`], with two extra rules the buffered
/// plane needs:
///
/// * **exactly-once per grant** — a push is accepted only from the
///   grant's current owner, and only once; late or duplicate pushes for
///   a cut/accepted grant are refused.
/// * **per-client serialization** — a client with an unresolved grant
///   (in flight *or* accepted-but-not-yet-folded) can not be granted
///   again: its state only advances when a fold installs it, so a second
///   concurrent grant would ship a stale state and break replay parity.
///   [`AsyncBook::release`] frees the client when its arrival folds.
#[derive(Clone, Debug, Default)]
pub struct AsyncBook {
    /// grant → (client, owner worker, born epoch) while in flight.
    pending: BTreeMap<u64, (usize, usize, u64)>,
    /// Accepted, buffered, not yet folded.
    arrived: BTreeSet<u64>,
    cut: BTreeSet<u64>,
    /// Clients with an unresolved grant (pending or arrived-unfolded).
    busy: BTreeSet<usize>,
}

impl AsyncBook {
    /// Open a grant: lease `client` to worker `widx`. False (and a no-op)
    /// when the grant id was already used or the client is busy.
    pub fn grant(&mut self, grant: u64, client: usize, widx: usize, born_epoch: u64) -> bool {
        if self.busy.contains(&client)
            || self.pending.contains_key(&grant)
            || self.arrived.contains(&grant)
            || self.cut.contains(&grant)
        {
            return false;
        }
        self.pending.insert(grant, (client, widx, born_epoch));
        self.busy.insert(client);
        true
    }

    pub fn owner(&self, grant: u64) -> Option<usize> {
        self.pending.get(&grant).map(|&(_, w, _)| w)
    }

    pub fn client_of(&self, grant: u64) -> Option<usize> {
        self.pending.get(&grant).map(|&(c, _, _)| c)
    }

    pub fn born_epoch(&self, grant: u64) -> Option<u64> {
        self.pending.get(&grant).map(|&(_, _, e)| e)
    }

    pub fn is_busy(&self, client: usize) -> bool {
        self.busy.contains(&client)
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    /// All in-flight grant ids, ascending.
    pub fn pending_ids(&self) -> Vec<u64> {
        self.pending.keys().copied().collect()
    }

    /// In-flight grants currently owned by `widx`, ascending.
    pub fn pending_of(&self, widx: usize) -> Vec<u64> {
        self.pending
            .iter()
            .filter(|(_, &(_, w, _))| w == widx)
            .map(|(&g, _)| g)
            .collect()
    }

    /// Accept a push for `grant` from worker `widx` — the exactly-once
    /// gate. True only when the grant is in flight and `widx` owns it.
    /// The client stays busy until [`AsyncBook::release`].
    pub fn accept(&mut self, grant: u64, widx: usize) -> bool {
        match self.pending.get(&grant) {
            Some(&(_, w, _)) if w == widx => {
                self.pending.remove(&grant);
                self.arrived.insert(grant);
                true
            }
            _ => false,
        }
    }

    /// Cut one in-flight grant (disconnect, malformed push, deadline).
    /// Frees the client for a fresh grant. False when the grant already
    /// arrived or was already cut.
    pub fn cut(&mut self, grant: u64) -> bool {
        let Some((client, _, _)) = self.pending.remove(&grant) else {
            return false;
        };
        self.cut.insert(grant);
        self.busy.remove(&client);
        true
    }

    /// Cut every in-flight grant of `widx` (disconnect). Returns the cut
    /// grant ids, ascending.
    pub fn cut_pending_of(&mut self, widx: usize) -> Vec<u64> {
        let lost = self.pending_of(widx);
        for g in &lost {
            self.cut(*g);
        }
        lost
    }

    /// A fold installed `grant`'s state: the arrival resolves and its
    /// client may be granted again. False unless the grant was in the
    /// arrived-unfolded set.
    pub fn release(&mut self, grant: u64, client: usize) -> bool {
        if !self.arrived.remove(&grant) {
            return false;
        }
        self.busy.remove(&client);
        true
    }

    /// All cut grant ids, ascending.
    pub fn cuts(&self) -> Vec<u64> {
        self.cut.iter().copied().collect()
    }

    /// Ledger invariants (property-tested): pending, arrived, and cut are
    /// pairwise disjoint; every pending grant's client is busy.
    pub fn check_invariants(&self) -> Result<(), String> {
        for g in self.pending.keys() {
            if self.arrived.contains(g) || self.cut.contains(g) {
                return Err(format!("grant {g} pending and resolved"));
            }
        }
        if let Some(g) = self.arrived.intersection(&self.cut).next() {
            return Err(format!("grant {g} both arrived and cut"));
        }
        for (g, &(c, _, _)) in &self.pending {
            if !self.busy.contains(&c) {
                return Err(format!("grant {g} pending but client {c} not busy"));
            }
        }
        Ok(())
    }
}

/// Per-round client-lease ledger: which worker owns each runnable
/// client's lease, who arrived, who was cut. `net::server` dispatches,
/// migrates, and folds through this, and the ledger enforces the
/// **exactly-once invariant**: a client's update is accepted at most once
/// per round, and only from its *current* lease holder — a stale push
/// from a migrated-away or crashed-and-replaced worker is refused, never
/// double-folded. Property-tested in `tests/props_chaos.rs`.
#[derive(Clone, Debug, Default)]
pub struct LeaseBook {
    /// client → sampled slot (the deterministic fold position).
    slot_of: BTreeMap<usize, usize>,
    /// client → owning worker index. Migration rewrites this.
    owner: BTreeMap<usize, usize>,
    pending: BTreeSet<usize>,
    arrived: BTreeSet<usize>,
    cut: BTreeSet<usize>,
}

impl LeaseBook {
    /// Open the round's ledger over the runnable `(client, steps)` list
    /// in sampled order (slot = position).
    pub fn new(runnable: &[(usize, u64)]) -> LeaseBook {
        let mut book = LeaseBook::default();
        for (slot, &(client, _)) in runnable.iter().enumerate() {
            book.slot_of.insert(client, slot);
        }
        book
    }

    /// Lease `client` to worker `widx` at dispatch. Panics in debug if the
    /// client was not declared runnable.
    pub fn lease(&mut self, client: usize, widx: usize) {
        debug_assert!(self.slot_of.contains_key(&client), "lease of unsampled client");
        self.owner.insert(client, widx);
        self.pending.insert(client);
    }

    pub fn slot(&self, client: usize) -> Option<usize> {
        self.slot_of.get(&client).copied()
    }

    pub fn owner(&self, client: usize) -> Option<usize> {
        self.owner.get(&client).copied()
    }

    /// True iff every client is sampled and their slots are strictly
    /// increasing — i.e. the list is duplicate-free and in sampled order.
    /// This is the member-order rule a `FoldedPush` must satisfy: the
    /// root re-derives the carried weight as the *sequential* sum over
    /// the members in slot order at commit, so a push folded (or merely
    /// summed) in any other order could carry a weight the commit-time
    /// verification would reject only after the round is already
    /// ledgered — a crash, not a cut.
    pub fn slots_strictly_increasing(&self, clients: &[usize]) -> bool {
        let mut prev: Option<usize> = None;
        for &c in clients {
            let Some(slot) = self.slot(c) else {
                return false;
            };
            if prev.is_some_and(|p| p >= slot) {
                return false;
            }
            prev = Some(slot);
        }
        true
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn arrived_count(&self) -> usize {
        self.arrived.len()
    }

    /// Pending leases currently held by `widx`, ascending.
    pub fn pending_of(&self, widx: usize) -> Vec<usize> {
        self.pending
            .iter()
            .copied()
            .filter(|c| self.owner.get(c) == Some(&widx))
            .collect()
    }

    /// Accept a push for `client` from worker `widx`. True only when the
    /// client is still pending *and* `widx` holds its lease — the
    /// exactly-once gate.
    pub fn accept(&mut self, client: usize, widx: usize) -> bool {
        if self.owner.get(&client) != Some(&widx) || !self.pending.remove(&client) {
            return false;
        }
        self.arrived.insert(client);
        true
    }

    /// Cut one pending client (deadline/disconnect/malformed push).
    /// False when the client already arrived or was already cut.
    pub fn cut(&mut self, client: usize) -> bool {
        if !self.pending.remove(&client) {
            return false;
        }
        self.cut.insert(client);
        true
    }

    /// Deadline fired: cut everything still pending. Returns the count.
    pub fn cut_all_pending(&mut self) -> usize {
        let n = self.pending.len();
        let pending = std::mem::take(&mut self.pending);
        self.cut.extend(pending);
        n
    }

    /// Cut every pending lease of `widx` (immediate disconnect-cut when
    /// no deadline bounds a rejoin window). Returns the cut clients.
    pub fn cut_pending_of(&mut self, widx: usize) -> Vec<usize> {
        let lost = self.pending_of(widx);
        for c in &lost {
            self.pending.remove(c);
            self.cut.insert(*c);
        }
        lost
    }

    /// Move every pending lease of `from` onto `targets`, round-robin in
    /// ascending client order. Returns the realized migrations (empty
    /// when `targets` is empty — leases then stay with `from` for the
    /// deadline or a rejoin to resolve).
    pub fn migrate_from(&mut self, from: usize, targets: &[usize]) -> Vec<Migration> {
        if targets.is_empty() {
            return Vec::new();
        }
        self.pending_of(from)
            .into_iter()
            .enumerate()
            .map(|(i, client)| {
                let to = targets[i % targets.len()];
                self.owner.insert(client, to);
                Migration { client, from, to }
            })
            .collect()
    }

    /// The realized cut schedule, ascending — what
    /// `Federation::run_round_cut` replays.
    pub fn cuts(&self) -> Vec<usize> {
        self.cut.iter().copied().collect()
    }

    /// Ledger invariants (used by the property tests): arrived and cut
    /// are disjoint, and everything accounted for was actually leased.
    pub fn check_invariants(&self) -> Result<(), String> {
        if let Some(c) = self.arrived.intersection(&self.cut).next() {
            return Err(format!("client {c} both arrived and cut"));
        }
        for c in self.arrived.iter().chain(&self.cut).chain(&self.pending) {
            if !self.owner.contains_key(c) {
                return Err(format!("client {c} tracked without a lease"));
            }
            if !self.slot_of.contains_key(c) {
                return Err(format!("client {c} tracked without a slot"));
            }
        }
        Ok(())
    }

    /// Group the realized migrations of one round by their target (used
    /// by the server to batch re-dispatch frames).
    pub fn group_by_target(migs: &[Migration]) -> BTreeMap<usize, Vec<usize>> {
        let mut per: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for m in migs {
            per.entry(m.to).or_default().push(m.client);
        }
        per
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schedule(seed: u64) -> Schedule {
        Schedule::generate(seed, 4, 20, ChaosConfig::at_rate(0.5))
    }

    #[test]
    fn schedule_is_deterministic_and_extent_stable() {
        let a = schedule(7);
        let b = schedule(7);
        for r in 0..20 {
            for w in 0..4 {
                assert_eq!(a.fault(w, r), b.fault(w, r));
            }
        }
        // Extending the plan never rewrites existing cells.
        let wide = Schedule::generate(7, 8, 40, ChaosConfig::at_rate(0.5));
        for r in 0..20 {
            for w in 0..4 {
                assert_eq!(a.fault(w, r), wide.fault(w, r), "cell ({w},{r})");
            }
        }
        assert_ne!(
            (0..20).map(|r| schedule(7).fault(1, r)).collect::<Vec<_>>(),
            (0..20).map(|r| schedule(8).fault(1, r)).collect::<Vec<_>>(),
            "different seeds must differ somewhere"
        );
    }

    #[test]
    fn worker0_is_protected_from_fatal_faults() {
        let s = Schedule::generate(3, 4, 200, ChaosConfig::at_rate(0.9));
        for r in 0..200 {
            assert!(
                !matches!(s.fault(0, r), Fault::Crash { .. } | Fault::Hang),
                "round {r}"
            );
        }
    }

    #[test]
    fn quiet_schedule_and_deadline_need() {
        let quiet = Schedule::generate(1, 4, 10, ChaosConfig::none());
        assert!(quiet.is_quiet());
        assert!(!quiet.needs_deadline());
        let noisy = Schedule::generate(1, 4, 50, ChaosConfig::at_rate(0.8));
        assert!(!noisy.is_quiet());
        assert!(noisy.needs_deadline(), "hang/flake cells need a deadline");
    }

    #[test]
    fn worker_view_matches_and_consume_clears() {
        let s = schedule(11);
        let mut w = s.worker(2);
        for r in 0..20u64 {
            assert_eq!(w.fault(r), s.fault(2, r as usize));
        }
        let crashed = (0..20u64).find(|r| matches!(w.fault(*r), Fault::Crash { .. }));
        if let Some(r) = crashed {
            w.consume(r);
            assert_eq!(w.fault(r), Fault::None);
        }
        assert_eq!(w.fault(10_000), Fault::None, "beyond the plan = quiet");
    }

    #[test]
    fn lease_book_exactly_once() {
        let runnable: Vec<(usize, u64)> = vec![(3, 10), (0, 10), (5, 10)];
        let mut book = LeaseBook::new(&runnable);
        assert_eq!(book.slot(3), Some(0));
        assert_eq!(book.slot(5), Some(2));
        book.lease(3, 0);
        book.lease(0, 1);
        book.lease(5, 0);
        assert_eq!(book.pending_of(0), vec![3, 5]);
        // Wrong owner refused; right owner accepted exactly once.
        assert!(!book.accept(3, 1));
        assert!(book.accept(3, 0));
        assert!(!book.accept(3, 0), "double push refused");
        // Migration moves the lease and the acceptance right with it.
        let migs = book.migrate_from(0, &[1]);
        assert_eq!(migs, vec![Migration { client: 5, from: 0, to: 1 }]);
        assert!(!book.accept(5, 0), "stale owner refused after migration");
        assert!(book.accept(5, 1));
        assert!(book.cut(0));
        assert!(!book.cut(0));
        assert_eq!(book.cuts(), vec![0]);
        assert_eq!(book.arrived_count(), 2);
        assert_eq!(book.pending_count(), 0);
        book.check_invariants().unwrap();
    }

    #[test]
    fn lease_book_bulk_cuts() {
        let runnable: Vec<(usize, u64)> = (0..6).map(|c| (c, 5)).collect();
        let mut book = LeaseBook::new(&runnable);
        for c in 0..6 {
            book.lease(c, c % 2);
        }
        assert_eq!(book.cut_pending_of(1), vec![1, 3, 5]);
        assert!(book.accept(0, 0));
        assert_eq!(book.cut_all_pending(), 2);
        assert_eq!(book.cuts(), vec![1, 2, 3, 4, 5]);
        book.check_invariants().unwrap();
    }

    #[test]
    fn flaked_frames_never_decode() {
        let payload: Vec<f32> = (0..300).map(|i| (i as f32 * 0.31).cos()).collect();
        for compress in [false, true] {
            let clean =
                crate::link::encode_model(crate::link::MsgKind::ClientUpdate, &payload, compress)
                    .unwrap();
            assert!(crate::link::decode_model(&clean).is_ok());
            for seed in 0..64u64 {
                let mut bad = clean.clone();
                flake_frame(&mut bad, seed);
                assert!(
                    crate::link::decode_model(&bad).is_err(),
                    "flake seed {seed} (compress {compress}) must be rejected"
                );
            }
        }
        // Header-only frames (empty payload) are flaked via the checksum.
        let mut empty =
            crate::link::encode_model(crate::link::MsgKind::Metrics, &[], false).unwrap();
        flake_frame(&mut empty, 9);
        assert!(crate::link::decode_model(&empty).is_err());
    }

    #[test]
    fn apply_to_plan_prices_churn() {
        let plan = RoundPlan {
            n_clients: 8,
            tau: 10,
            rounds: (0..20)
                .map(|round| RoundSpec {
                    round,
                    participants: (0..8)
                        .map(|client| Participant { client, steps: 10, straggler: false })
                        .collect(),
                    dropped: vec![],
                })
                .collect(),
        };
        let s = Schedule::generate(5, 4, 20, ChaosConfig::at_rate(0.6));
        let cut = s.apply_to_plan(&plan, false);
        let migrated = s.apply_to_plan(&plan, true);
        assert_eq!(cut.rounds.len(), 20);
        let total =
            |p: &RoundPlan| p.rounds.iter().map(|r| r.participants.len()).sum::<usize>();
        assert!(
            total(&cut) < total(&plan),
            "churn must remove participants ({} vs {})",
            total(&cut),
            total(&plan)
        );
        assert!(
            total(&migrated) >= total(&cut),
            "lease migration keeps crashed/hung workers' clients running"
        );
        // Every round conserves the sample: participants + dropped = 8.
        for r in &cut.rounds {
            assert_eq!(r.participants.len() + r.dropped.len(), 8, "round {}", r.round);
        }
        // Determinism.
        assert_eq!(cut, s.apply_to_plan(&plan, false));
    }

    #[test]
    fn discounted_weights_basics() {
        // gamma = 1 disables the discount: plain normalized base weights.
        let w = discounted_weights(&[160.0, 160.0, 320.0], &[0, 3, 1], 1.0);
        assert_eq!(w[0].to_bits(), (160.0f64 / 640.0).to_bits());
        assert_eq!(w[2].to_bits(), (320.0f64 / 640.0).to_bits());
        // gamma < 1 discounts stale arrivals; weights still sum to 1.
        let w = discounted_weights(&[100.0, 100.0], &[0, 2], 0.5);
        assert!(w[1] < w[0], "staler arrival must weigh less");
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(w[1].to_bits(), (25.0f64 / 125.0).to_bits());
        // Determinism: bit-identical on re-derivation.
        let a = discounted_weights(&[7.0, 11.0, 13.0], &[2, 0, 5], 0.9);
        let b = discounted_weights(&[7.0, 11.0, 13.0], &[2, 0, 5], 0.9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn async_book_exactly_once_and_serialization() {
        let mut book = AsyncBook::default();
        assert!(book.grant(0, 3, 0, 0));
        assert!(book.grant(1, 5, 1, 0));
        assert!(!book.grant(2, 3, 1, 0), "busy client refused a second grant");
        assert!(!book.grant(0, 7, 1, 0), "grant id reuse refused");
        assert_eq!(book.owner(0), Some(0));
        assert_eq!(book.client_of(1), Some(5));
        assert_eq!(book.born_epoch(1), Some(0));
        // Wrong owner refused; right owner accepted exactly once.
        assert!(!book.accept(0, 1));
        assert!(book.accept(0, 0));
        assert!(!book.accept(0, 0), "double push refused");
        // Client stays busy until the fold releases it.
        assert!(book.is_busy(3));
        assert!(!book.grant(2, 3, 0, 1));
        assert!(book.release(0, 3));
        assert!(!book.release(0, 3), "double release refused");
        assert!(!book.is_busy(3));
        assert!(book.grant(2, 3, 0, 1), "released client grantable again");
        // Disconnect cuts in-flight grants and frees their clients.
        assert_eq!(book.cut_pending_of(1), vec![1]);
        assert!(!book.is_busy(5));
        assert!(!book.cut(1), "already-cut grant refused");
        assert_eq!(book.cuts(), vec![1]);
        book.check_invariants().unwrap();
    }

    #[test]
    fn async_trace_exactly_once_accounting() {
        let g = |grant, client, born_epoch| AsyncGrant {
            grant,
            client,
            steps: 4,
            born_epoch,
            seq_base: born_epoch * 4,
        };
        let ok = AsyncTrace {
            k: 2,
            gamma: 0.5,
            grants: vec![g(0, 0, 0), g(1, 1, 0), g(2, 2, 0), g(3, 3, 0), g(4, 0, 1)],
            folds: vec![
                AsyncFold {
                    epoch: 0,
                    arrivals: vec![
                        AsyncArrival { grant: 0, client: 0, staleness: 0, weight: 0.5 },
                        AsyncArrival { grant: 2, client: 2, staleness: 0, weight: 0.5 },
                    ],
                },
                AsyncFold {
                    epoch: 1,
                    arrivals: vec![
                        AsyncArrival { grant: 1, client: 1, staleness: 1, weight: 0.5 },
                        AsyncArrival { grant: 3, client: 3, staleness: 1, weight: 0.5 },
                    ],
                },
            ],
            cut: vec![4],
        };
        ok.check_exactly_once().unwrap();
        assert_eq!(ok.total_folded(), 4);
        assert_eq!(ok.total_cut(), 1);
        assert_eq!(ok.staleness_max(), 1);
        assert!((ok.staleness_mean() - 0.5).abs() < 1e-12);
        assert_eq!(ok.grant(4).map(|g| g.client), Some(0));

        // Double resolution (fold + cut) must be rejected.
        let mut bad = ok.clone();
        bad.cut.push(3);
        assert!(bad.check_exactly_once().is_err());
        // Unresolved grant must be rejected.
        let mut bad = ok.clone();
        bad.cut.clear();
        assert!(bad.check_exactly_once().is_err());
        // Non-canonical arrival order must be rejected.
        let mut bad = ok.clone();
        bad.folds[0].arrivals.swap(0, 1);
        assert!(bad.check_exactly_once().is_err());
        // Staleness inconsistent with born epoch must be rejected.
        let mut bad = ok;
        bad.folds[1].arrivals[0].staleness = 0;
        assert!(bad.check_exactly_once().is_err());
    }

    #[test]
    fn trace_accessors() {
        let t = Trace {
            rounds: vec![
                RoundTrace {
                    round: 1,
                    cut: vec![2, 5],
                    migrations: vec![Migration { client: 3, from: 0, to: 1 }],
                    rejoined: vec![2],
                },
                RoundTrace { round: 4, cut: vec![1], ..RoundTrace::default() },
            ],
        };
        assert_eq!(t.cut_for(1), &[2, 5]);
        assert!(t.cut_for(0).is_empty());
        assert_eq!(t.total_cut(), 3);
        assert_eq!(t.total_migrated(), 1);
        assert_eq!(t.total_rejoined(), 1);
        assert!(!t.is_quiet());
        assert!(Trace::default().is_quiet());
    }
}
