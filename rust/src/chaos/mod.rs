//! Seeded chaos injection for the elastic deployment plane (paper §5:
//! federated pre-training is "highly resilient to the classical challenges
//! of federated statistical and hardware heterogeneity" and "robust to
//! partial participation"; Photon, arXiv:2411.02908: stateless LLM Nodes
//! crash, rejoin, and migrate work without derailing the run).
//!
//! The subsystem has four pieces, all deterministic from one seed:
//!
//! * [`Schedule`] — a seed-derived fault plan, one [`Fault`] per
//!   (worker, round): crash (with optional rejoin-after-delay),
//!   hang-past-deadline, slow-down factor, or a link flake that corrupts
//!   one wire frame. `net::harness::run_loopback` injects it into the
//!   worker threads; [`Schedule::apply_to_plan`] prices the same churn
//!   into a [`sim`](crate::sim) round plan.
//! * [`flake_frame`] — deterministic corruption of a Photon-Link frame
//!   (payload bit flip, checksum flip, or truncation). A flaked frame is
//!   *rejected* by the link decoder, never mis-decoded — property-tested
//!   in `tests/props_chaos.rs`.
//! * [`LeaseBook`] — the per-round client-lease ledger `net::server`
//!   dispatches through: who owns each runnable client, who arrived, who
//!   was cut. It enforces **exactly-once client execution per round**
//!   (a push folds only from the current lease holder, and only once),
//!   which is what keeps mid-round lease migration and worker rejoin
//!   bit-compatible with the dropped-client path.
//! * [`Trace`] — the *realized* outcome of a chaotic run (cuts, lease
//!   migrations, rejoins per round), assembled by `net::Server::trace`.
//!   `Federation::run_round_trace` replays it in-process: since worker
//!   identity never affects the math, the replay reduces to the cut
//!   schedule, and a chaotic TCP run stays bit-equal to its replay.
//!
//! ## Determinism
//!
//! Every fault cell is derived per (seed, worker, round) exactly like
//! [`crate::cluster::faults::FaultPlan`] derives client faults, so the
//! *schedule* is reproducible — extending a schedule to more rounds or
//! workers never changes existing cells. The *realization* (which clients
//! actually get cut under real scheduling jitter) is captured in the
//! [`Trace`], and the parity contract is on the trace: any realization
//! replays bit-exactly.

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use crate::sim::{Participant, RoundPlan, RoundSpec};
use crate::util::rng::Rng;

/// Domain-separation tag so chaos draws never correlate with the client
/// [`FaultPlan`](crate::cluster::faults::FaultPlan) draws sharing a seed.
const CHAOS_TAG: u64 = 0xC8A0_5EED_0F1E_E75C;

/// One worker's misbehavior in one round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Behave normally.
    None,
    /// Disconnect on receiving the round's assignment, before replying.
    /// `rejoin_after_ms` brings the worker back (with its identity) after
    /// a delay; `None` means gone for good.
    Crash { rejoin_after_ms: Option<u64> },
    /// Stay connected but sit the round out: acknowledge the assignment,
    /// never push an update. The server's deadline (or lease migration)
    /// handles the silence.
    Hang,
    /// Serve the round `factor`× slower (a sleep before every push) —
    /// exercises late arrivals and the straggler-migration path.
    Slow { factor: f64 },
    /// Corrupt the wire frame of one `UpdatePush` (the `victim`-th task
    /// of the assignment, modulo its length) via [`flake_frame`] with
    /// `seed`. The server must reject the frame, never mis-decode it;
    /// the affected client is cut at the deadline like any straggler.
    Flake { victim: u32, seed: u64 },
}

/// Per-kind fault probabilities for [`Schedule::generate`]. The draws are
/// mutually exclusive per cell (one fault at most), evaluated in the
/// order crash → hang → slow → flake.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosConfig {
    pub crash_prob: f64,
    pub hang_prob: f64,
    pub slow_prob: f64,
    pub flake_prob: f64,
    /// P(a crashed worker rejoins with its identity after a delay).
    pub rejoin_prob: f64,
    /// Upper bound on the rejoin delay (drawn uniformly in
    /// `[rejoin_delay_ms/2, rejoin_delay_ms]`).
    pub rejoin_delay_ms: u64,
    /// Upper bound on the slow-down factor (drawn in `[1, slow_factor]`).
    pub slow_factor: f64,
    /// Never crash or hang worker 0, so every round keeps at least one
    /// live executor and the run always terminates. Slow-downs and flakes
    /// still apply to it.
    pub protect_worker0: bool,
}

impl ChaosConfig {
    /// A quiet fleet (every cell draws [`Fault::None`]).
    pub fn none() -> ChaosConfig {
        ChaosConfig {
            crash_prob: 0.0,
            hang_prob: 0.0,
            slow_prob: 0.0,
            flake_prob: 0.0,
            rejoin_prob: 0.0,
            rejoin_delay_ms: 40,
            slow_factor: 3.0,
            protect_worker0: true,
        }
    }

    /// Split an aggregate per-cell fault rate across the four kinds with
    /// the default mix (crash-heavy, as in the paper's dropout framing).
    pub fn at_rate(rate: f64) -> ChaosConfig {
        ChaosConfig {
            crash_prob: rate * 0.35,
            hang_prob: rate * 0.25,
            slow_prob: rate * 0.20,
            flake_prob: rate * 0.20,
            rejoin_prob: 0.75,
            ..ChaosConfig::none()
        }
    }

    /// Total per-cell fault probability.
    pub fn total_rate(&self) -> f64 {
        self.crash_prob + self.hang_prob + self.slow_prob + self.flake_prob
    }
}

/// A deterministic, seed-derived fault plan over `workers × rounds`.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    pub seed: u64,
    pub workers: usize,
    pub rounds: usize,
    pub cfg: ChaosConfig,
}

impl Schedule {
    pub fn generate(seed: u64, workers: usize, rounds: usize, cfg: ChaosConfig) -> Schedule {
        Schedule { seed, workers, rounds, cfg }
    }

    /// The fault of one (worker, round) cell. Derived per cell — never
    /// from shared RNG state — so cells are independent of the schedule's
    /// extent and of each other.
    pub fn fault(&self, worker: usize, round: usize) -> Fault {
        if round >= self.rounds || worker >= self.workers {
            return Fault::None;
        }
        let mut rng = Rng::new(
            self.seed
                ^ CHAOS_TAG
                ^ (round as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ ((worker as u64).wrapping_add(1)).wrapping_mul(0xD1B54A32D192ED03),
        );
        let x = rng.f64();
        let c = &self.cfg;
        let fault = if x < c.crash_prob {
            let rejoin = rng.bool(c.rejoin_prob);
            let half = (c.rejoin_delay_ms / 2).max(1);
            let delay = half + rng.below(c.rejoin_delay_ms.saturating_sub(half).max(1));
            Fault::Crash { rejoin_after_ms: rejoin.then_some(delay) }
        } else if x < c.crash_prob + c.hang_prob {
            Fault::Hang
        } else if x < c.crash_prob + c.hang_prob + c.slow_prob {
            Fault::Slow { factor: 1.0 + rng.f64() * (c.slow_factor - 1.0).max(0.0) }
        } else if x < c.total_rate() {
            Fault::Flake { victim: rng.below(1 << 16) as u32, seed: rng.next_u64() }
        } else {
            Fault::None
        };
        if c.protect_worker0
            && worker == 0
            && matches!(fault, Fault::Crash { .. } | Fault::Hang)
        {
            return Fault::None;
        }
        fault
    }

    /// One worker's view of the plan, ready to move into its thread.
    pub fn worker(&self, worker: usize) -> WorkerChaos {
        WorkerChaos {
            worker,
            faults: (0..self.rounds).map(|r| self.fault(worker, r)).collect(),
        }
    }

    /// True when any cell hangs or flakes — those faults leave clients
    /// pending on a live connection, so the fleet needs a per-round
    /// deadline to cut them (crashes alone cut on disconnect).
    pub fn needs_deadline(&self) -> bool {
        (0..self.rounds).any(|r| {
            (0..self.workers).any(|w| {
                matches!(self.fault(w, r), Fault::Hang | Fault::Flake { .. })
            })
        })
    }

    /// True when every cell is [`Fault::None`].
    pub fn is_quiet(&self) -> bool {
        (0..self.rounds)
            .all(|r| (0..self.workers).all(|w| self.fault(w, r) == Fault::None))
    }

    /// Price this schedule's churn into a simulator round plan, mirroring
    /// the server's dispatch rule (sampled slot s → s-th live worker,
    /// round-robin): clients of crashed/hung workers drop (or survive via
    /// lease migration when `migrate`), flake victims drop, clients of
    /// slowed workers straggle. Crashed workers with a rejoin delay miss
    /// only the crash round; without one they stay dead. This is the
    /// *pricing* model for `photon exp chaos` wall-clock estimates — the
    /// bit-parity contract lives in [`Trace`], not here.
    pub fn apply_to_plan(&self, plan: &RoundPlan, migrate: bool) -> RoundPlan {
        let mut live = vec![true; self.workers.max(1)];
        let mut rejoin_at: Vec<Option<usize>> = vec![None; self.workers.max(1)];
        let mut rounds = Vec::with_capacity(plan.rounds.len());
        for spec in &plan.rounds {
            let r = spec.round;
            for w in 0..live.len() {
                if rejoin_at[w] == Some(r) {
                    live[w] = true;
                    rejoin_at[w] = None;
                }
            }
            let live_idx: Vec<usize> = (0..live.len()).filter(|&w| live[w]).collect();
            let mut participants = Vec::new();
            let mut dropped = spec.dropped.clone();
            if live_idx.is_empty() {
                dropped.extend(spec.participants.iter().map(|p| p.client));
                rounds.push(RoundSpec { round: r, participants, dropped });
                continue;
            }
            // Per-worker task lists in dispatch order (for flake victims).
            let mut task_of: Vec<Vec<usize>> = vec![Vec::new(); live.len()];
            for (slot, _) in spec.participants.iter().enumerate() {
                task_of[live_idx[slot % live_idx.len()]].push(slot);
            }
            for (slot, p) in spec.participants.iter().enumerate() {
                let w = live_idx[slot % live_idx.len()];
                match self.fault(w, r) {
                    Fault::Crash { .. } | Fault::Hang if !migrate => {
                        dropped.push(p.client)
                    }
                    Fault::Flake { victim, .. }
                        if task_of[w][victim as usize % task_of[w].len()] == slot =>
                    {
                        dropped.push(p.client)
                    }
                    Fault::Slow { .. } => {
                        participants.push(Participant { straggler: true, ..p.clone() })
                    }
                    _ => participants.push(p.clone()),
                }
            }
            for &w in &live_idx {
                if let Fault::Crash { rejoin_after_ms } = self.fault(w, r) {
                    live[w] = false;
                    if rejoin_after_ms.is_some() {
                        rejoin_at[w] = Some(r + 1);
                    }
                }
            }
            rounds.push(RoundSpec { round: r, participants, dropped });
        }
        RoundPlan { n_clients: plan.n_clients, tau: plan.tau, rounds }
    }
}

/// One worker's slice of a [`Schedule`], movable into its thread.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerChaos {
    pub worker: usize,
    faults: Vec<Fault>,
}

impl WorkerChaos {
    pub fn fault(&self, round: u64) -> Fault {
        self.faults
            .get(round as usize)
            .copied()
            .unwrap_or(Fault::None)
    }

    /// Clear one round's fault — the harness consumes a crash before the
    /// worker rejoins, so the re-dispatched round does not crash it again
    /// in a loop.
    pub fn consume(&mut self, round: u64) {
        if let Some(f) = self.faults.get_mut(round as usize) {
            *f = Fault::None;
        }
    }
}

/// Deterministically corrupt a Photon-Link frame so its decode **fails**
/// (the link checksum/length/flag validation rejects it). Variants:
/// payload bit flip, truncation, or checksum-only damage — and *every*
/// variant also flips one bit of the stored FNV-1a checksum, so the frame
/// can never checksum-match whatever payload the decoder reconstructs.
/// (A lone payload flip could land in deflate padding bits and inflate
/// back to the original bytes; the unconditional checksum flip closes
/// that hole — a flaked frame is rejected, never silently mis-decoded.)
pub fn flake_frame(frame: &mut Vec<u8>, seed: u64) {
    let hdr = crate::link::HEADER_BYTES;
    let mut rng = Rng::new(seed ^ 0xF1A4_EF1A_4EF1_A4EF);
    if frame.len() < hdr {
        // Already unframeable; shorten it further for variety.
        frame.truncate(frame.len() / 2);
        return;
    }
    let variant = rng.below(3);
    if variant == 2 && frame.len() > hdr + 1 {
        // Truncate somewhere inside the payload...
        let keep = hdr + rng.usize_below(frame.len() - hdr);
        frame.truncate(keep.max(hdr));
    } else if variant == 1 && frame.len() > hdr {
        // ...or flip one payload bit...
        let i = hdr + rng.usize_below(frame.len() - hdr);
        frame[i] ^= 1 << rng.below(8);
    }
    // ...and always defeat the integrity check: flip one bit of the
    // stored checksum (bytes 20..28). The odds of the damaged payload
    // FNV-hashing onto the damaged checksum are 2⁻⁶⁴.
    let i = 20 + rng.usize_below(8);
    frame[i] ^= 1 << rng.below(8);
}

/// One realized client-lease migration: `client`'s lease moved from
/// worker slot `from` to slot `to` mid-round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Migration {
    pub client: usize,
    pub from: usize,
    pub to: usize,
}

/// The realized fate of one round of a chaotic deployment-plane run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundTrace {
    pub round: usize,
    /// Clients cut from the aggregation (deadline, disconnect, malformed
    /// push) — the only field that affects the replayed math.
    pub cut: Vec<usize>,
    /// Leases migrated to live workers before the deadline.
    pub migrations: Vec<Migration>,
    /// Worker slots that rejoined with identity during the round.
    pub rejoined: Vec<usize>,
}

/// The realized trace of a whole run (sparse: only eventful rounds).
/// Assembled by `net::Server::trace`, replayed by
/// `Federation::run_trace` — the two must agree bit-for-bit on records
/// and the final global model (the ISSUE 5 acceptance invariant).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub rounds: Vec<RoundTrace>,
}

impl Trace {
    pub fn for_round(&self, round: usize) -> Option<&RoundTrace> {
        self.rounds.iter().find(|t| t.round == round)
    }

    /// The cut schedule of one round (empty when the round was clean).
    pub fn cut_for(&self, round: usize) -> &[usize] {
        self.for_round(round).map(|t| t.cut.as_slice()).unwrap_or(&[])
    }

    pub fn total_cut(&self) -> usize {
        self.rounds.iter().map(|t| t.cut.len()).sum()
    }

    pub fn total_migrated(&self) -> usize {
        self.rounds.iter().map(|t| t.migrations.len()).sum()
    }

    pub fn total_rejoined(&self) -> usize {
        self.rounds.iter().map(|t| t.rejoined.len()).sum()
    }

    pub fn is_quiet(&self) -> bool {
        self.rounds.is_empty()
    }
}

/// Per-round client-lease ledger: which worker owns each runnable
/// client's lease, who arrived, who was cut. `net::server` dispatches,
/// migrates, and folds through this, and the ledger enforces the
/// **exactly-once invariant**: a client's update is accepted at most once
/// per round, and only from its *current* lease holder — a stale push
/// from a migrated-away or crashed-and-replaced worker is refused, never
/// double-folded. Property-tested in `tests/props_chaos.rs`.
#[derive(Clone, Debug, Default)]
pub struct LeaseBook {
    /// client → sampled slot (the deterministic fold position).
    slot_of: BTreeMap<usize, usize>,
    /// client → owning worker index. Migration rewrites this.
    owner: BTreeMap<usize, usize>,
    pending: BTreeSet<usize>,
    arrived: BTreeSet<usize>,
    cut: BTreeSet<usize>,
}

impl LeaseBook {
    /// Open the round's ledger over the runnable `(client, steps)` list
    /// in sampled order (slot = position).
    pub fn new(runnable: &[(usize, u64)]) -> LeaseBook {
        let mut book = LeaseBook::default();
        for (slot, &(client, _)) in runnable.iter().enumerate() {
            book.slot_of.insert(client, slot);
        }
        book
    }

    /// Lease `client` to worker `widx` at dispatch. Panics in debug if the
    /// client was not declared runnable.
    pub fn lease(&mut self, client: usize, widx: usize) {
        debug_assert!(self.slot_of.contains_key(&client), "lease of unsampled client");
        self.owner.insert(client, widx);
        self.pending.insert(client);
    }

    pub fn slot(&self, client: usize) -> Option<usize> {
        self.slot_of.get(&client).copied()
    }

    pub fn owner(&self, client: usize) -> Option<usize> {
        self.owner.get(&client).copied()
    }

    /// True iff every client is sampled and their slots are strictly
    /// increasing — i.e. the list is duplicate-free and in sampled order.
    /// This is the member-order rule a `FoldedPush` must satisfy: the
    /// root re-derives the carried weight as the *sequential* sum over
    /// the members in slot order at commit, so a push folded (or merely
    /// summed) in any other order could carry a weight the commit-time
    /// verification would reject only after the round is already
    /// ledgered — a crash, not a cut.
    pub fn slots_strictly_increasing(&self, clients: &[usize]) -> bool {
        let mut prev: Option<usize> = None;
        for &c in clients {
            let Some(slot) = self.slot(c) else {
                return false;
            };
            if prev.is_some_and(|p| p >= slot) {
                return false;
            }
            prev = Some(slot);
        }
        true
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn arrived_count(&self) -> usize {
        self.arrived.len()
    }

    /// Pending leases currently held by `widx`, ascending.
    pub fn pending_of(&self, widx: usize) -> Vec<usize> {
        self.pending
            .iter()
            .copied()
            .filter(|c| self.owner.get(c) == Some(&widx))
            .collect()
    }

    /// Accept a push for `client` from worker `widx`. True only when the
    /// client is still pending *and* `widx` holds its lease — the
    /// exactly-once gate.
    pub fn accept(&mut self, client: usize, widx: usize) -> bool {
        if self.owner.get(&client) != Some(&widx) || !self.pending.remove(&client) {
            return false;
        }
        self.arrived.insert(client);
        true
    }

    /// Cut one pending client (deadline/disconnect/malformed push).
    /// False when the client already arrived or was already cut.
    pub fn cut(&mut self, client: usize) -> bool {
        if !self.pending.remove(&client) {
            return false;
        }
        self.cut.insert(client);
        true
    }

    /// Deadline fired: cut everything still pending. Returns the count.
    pub fn cut_all_pending(&mut self) -> usize {
        let n = self.pending.len();
        let pending = std::mem::take(&mut self.pending);
        self.cut.extend(pending);
        n
    }

    /// Cut every pending lease of `widx` (immediate disconnect-cut when
    /// no deadline bounds a rejoin window). Returns the cut clients.
    pub fn cut_pending_of(&mut self, widx: usize) -> Vec<usize> {
        let lost = self.pending_of(widx);
        for c in &lost {
            self.pending.remove(c);
            self.cut.insert(*c);
        }
        lost
    }

    /// Move every pending lease of `from` onto `targets`, round-robin in
    /// ascending client order. Returns the realized migrations (empty
    /// when `targets` is empty — leases then stay with `from` for the
    /// deadline or a rejoin to resolve).
    pub fn migrate_from(&mut self, from: usize, targets: &[usize]) -> Vec<Migration> {
        if targets.is_empty() {
            return Vec::new();
        }
        self.pending_of(from)
            .into_iter()
            .enumerate()
            .map(|(i, client)| {
                let to = targets[i % targets.len()];
                self.owner.insert(client, to);
                Migration { client, from, to }
            })
            .collect()
    }

    /// The realized cut schedule, ascending — what
    /// `Federation::run_round_cut` replays.
    pub fn cuts(&self) -> Vec<usize> {
        self.cut.iter().copied().collect()
    }

    /// Ledger invariants (used by the property tests): arrived and cut
    /// are disjoint, and everything accounted for was actually leased.
    pub fn check_invariants(&self) -> Result<(), String> {
        if let Some(c) = self.arrived.intersection(&self.cut).next() {
            return Err(format!("client {c} both arrived and cut"));
        }
        for c in self.arrived.iter().chain(&self.cut).chain(&self.pending) {
            if !self.owner.contains_key(c) {
                return Err(format!("client {c} tracked without a lease"));
            }
            if !self.slot_of.contains_key(c) {
                return Err(format!("client {c} tracked without a slot"));
            }
        }
        Ok(())
    }

    /// Group the realized migrations of one round by their target (used
    /// by the server to batch re-dispatch frames).
    pub fn group_by_target(migs: &[Migration]) -> BTreeMap<usize, Vec<usize>> {
        let mut per: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for m in migs {
            per.entry(m.to).or_default().push(m.client);
        }
        per
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schedule(seed: u64) -> Schedule {
        Schedule::generate(seed, 4, 20, ChaosConfig::at_rate(0.5))
    }

    #[test]
    fn schedule_is_deterministic_and_extent_stable() {
        let a = schedule(7);
        let b = schedule(7);
        for r in 0..20 {
            for w in 0..4 {
                assert_eq!(a.fault(w, r), b.fault(w, r));
            }
        }
        // Extending the plan never rewrites existing cells.
        let wide = Schedule::generate(7, 8, 40, ChaosConfig::at_rate(0.5));
        for r in 0..20 {
            for w in 0..4 {
                assert_eq!(a.fault(w, r), wide.fault(w, r), "cell ({w},{r})");
            }
        }
        assert_ne!(
            (0..20).map(|r| schedule(7).fault(1, r)).collect::<Vec<_>>(),
            (0..20).map(|r| schedule(8).fault(1, r)).collect::<Vec<_>>(),
            "different seeds must differ somewhere"
        );
    }

    #[test]
    fn worker0_is_protected_from_fatal_faults() {
        let s = Schedule::generate(3, 4, 200, ChaosConfig::at_rate(0.9));
        for r in 0..200 {
            assert!(
                !matches!(s.fault(0, r), Fault::Crash { .. } | Fault::Hang),
                "round {r}"
            );
        }
    }

    #[test]
    fn quiet_schedule_and_deadline_need() {
        let quiet = Schedule::generate(1, 4, 10, ChaosConfig::none());
        assert!(quiet.is_quiet());
        assert!(!quiet.needs_deadline());
        let noisy = Schedule::generate(1, 4, 50, ChaosConfig::at_rate(0.8));
        assert!(!noisy.is_quiet());
        assert!(noisy.needs_deadline(), "hang/flake cells need a deadline");
    }

    #[test]
    fn worker_view_matches_and_consume_clears() {
        let s = schedule(11);
        let mut w = s.worker(2);
        for r in 0..20u64 {
            assert_eq!(w.fault(r), s.fault(2, r as usize));
        }
        let crashed = (0..20u64).find(|r| matches!(w.fault(*r), Fault::Crash { .. }));
        if let Some(r) = crashed {
            w.consume(r);
            assert_eq!(w.fault(r), Fault::None);
        }
        assert_eq!(w.fault(10_000), Fault::None, "beyond the plan = quiet");
    }

    #[test]
    fn lease_book_exactly_once() {
        let runnable: Vec<(usize, u64)> = vec![(3, 10), (0, 10), (5, 10)];
        let mut book = LeaseBook::new(&runnable);
        assert_eq!(book.slot(3), Some(0));
        assert_eq!(book.slot(5), Some(2));
        book.lease(3, 0);
        book.lease(0, 1);
        book.lease(5, 0);
        assert_eq!(book.pending_of(0), vec![3, 5]);
        // Wrong owner refused; right owner accepted exactly once.
        assert!(!book.accept(3, 1));
        assert!(book.accept(3, 0));
        assert!(!book.accept(3, 0), "double push refused");
        // Migration moves the lease and the acceptance right with it.
        let migs = book.migrate_from(0, &[1]);
        assert_eq!(migs, vec![Migration { client: 5, from: 0, to: 1 }]);
        assert!(!book.accept(5, 0), "stale owner refused after migration");
        assert!(book.accept(5, 1));
        assert!(book.cut(0));
        assert!(!book.cut(0));
        assert_eq!(book.cuts(), vec![0]);
        assert_eq!(book.arrived_count(), 2);
        assert_eq!(book.pending_count(), 0);
        book.check_invariants().unwrap();
    }

    #[test]
    fn lease_book_bulk_cuts() {
        let runnable: Vec<(usize, u64)> = (0..6).map(|c| (c, 5)).collect();
        let mut book = LeaseBook::new(&runnable);
        for c in 0..6 {
            book.lease(c, c % 2);
        }
        assert_eq!(book.cut_pending_of(1), vec![1, 3, 5]);
        assert!(book.accept(0, 0));
        assert_eq!(book.cut_all_pending(), 2);
        assert_eq!(book.cuts(), vec![1, 2, 3, 4, 5]);
        book.check_invariants().unwrap();
    }

    #[test]
    fn flaked_frames_never_decode() {
        let payload: Vec<f32> = (0..300).map(|i| (i as f32 * 0.31).cos()).collect();
        for compress in [false, true] {
            let clean =
                crate::link::encode_model(crate::link::MsgKind::ClientUpdate, &payload, compress)
                    .unwrap();
            assert!(crate::link::decode_model(&clean).is_ok());
            for seed in 0..64u64 {
                let mut bad = clean.clone();
                flake_frame(&mut bad, seed);
                assert!(
                    crate::link::decode_model(&bad).is_err(),
                    "flake seed {seed} (compress {compress}) must be rejected"
                );
            }
        }
        // Header-only frames (empty payload) are flaked via the checksum.
        let mut empty =
            crate::link::encode_model(crate::link::MsgKind::Metrics, &[], false).unwrap();
        flake_frame(&mut empty, 9);
        assert!(crate::link::decode_model(&empty).is_err());
    }

    #[test]
    fn apply_to_plan_prices_churn() {
        let plan = RoundPlan {
            n_clients: 8,
            tau: 10,
            rounds: (0..20)
                .map(|round| RoundSpec {
                    round,
                    participants: (0..8)
                        .map(|client| Participant { client, steps: 10, straggler: false })
                        .collect(),
                    dropped: vec![],
                })
                .collect(),
        };
        let s = Schedule::generate(5, 4, 20, ChaosConfig::at_rate(0.6));
        let cut = s.apply_to_plan(&plan, false);
        let migrated = s.apply_to_plan(&plan, true);
        assert_eq!(cut.rounds.len(), 20);
        let total =
            |p: &RoundPlan| p.rounds.iter().map(|r| r.participants.len()).sum::<usize>();
        assert!(
            total(&cut) < total(&plan),
            "churn must remove participants ({} vs {})",
            total(&cut),
            total(&plan)
        );
        assert!(
            total(&migrated) >= total(&cut),
            "lease migration keeps crashed/hung workers' clients running"
        );
        // Every round conserves the sample: participants + dropped = 8.
        for r in &cut.rounds {
            assert_eq!(r.participants.len() + r.dropped.len(), 8, "round {}", r.round);
        }
        // Determinism.
        assert_eq!(cut, s.apply_to_plan(&plan, false));
    }

    #[test]
    fn trace_accessors() {
        let t = Trace {
            rounds: vec![
                RoundTrace {
                    round: 1,
                    cut: vec![2, 5],
                    migrations: vec![Migration { client: 3, from: 0, to: 1 }],
                    rejoined: vec![2],
                },
                RoundTrace { round: 4, cut: vec![1], ..RoundTrace::default() },
            ],
        };
        assert_eq!(t.cut_for(1), &[2, 5]);
        assert!(t.cut_for(0).is_empty());
        assert_eq!(t.total_cut(), 3);
        assert_eq!(t.total_migrated(), 1);
        assert_eq!(t.total_rejoined(), 1);
        assert!(!t.is_quiet());
        assert!(Trace::default().is_quiet());
    }
}
