//! Seeded property-testing harness (proptest is unavailable offline; see
//! DESIGN.md §1). `check` runs a property over `n` random cases; on failure
//! it reports the failing case seed so the case replays exactly with
//! `replay`.

use crate::util::rng::Rng;

/// Run `prop` over `n` random cases derived from `base_seed`. Panics with
/// the failing case seed on the first violation.
pub fn check<F>(name: &str, base_seed: u64, n: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..n {
        let case_seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{n} \
                 (replay seed: {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn replay<F>(name: &str, case_seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property {name:?} failed on replay {case_seed:#x}: {msg}");
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Random f32 vector in [-scale, scale].
pub fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 1, 25, |rng| {
            count += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always_fails", 2, 5, |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.00001], 1e-3).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }

    #[test]
    fn rand_vec_in_range() {
        let mut rng = Rng::new(3);
        let v = rand_vec(&mut rng, 100, 2.0);
        assert!(v.iter().all(|&x| (-2.0..=2.0).contains(&x)));
    }
}
