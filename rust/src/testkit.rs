//! Seeded property-testing harness (proptest is unavailable offline; see
//! DESIGN.md §1). [`check`] runs a property over `n` random cases; on
//! failure it reports the failing case seed so the case replays exactly —
//! either programmatically with [`replay`], or without touching code by
//! exporting `TESTKIT_REPLAY=<seed>` and re-running the test.
//!
//! [`check_cases`] adds minimal-case **shrinking**: the case is an explicit
//! value built by a generator callback, and on failure a `shrink` callback
//! proposes smaller candidates (halved sizes, zeroed fields); the harness
//! keeps the smallest candidate that still fails and reports it alongside
//! the seed. See `docs/TESTING.md` for the workflow.

use crate::util::rng::Rng;

/// Env var that replays one reported case seed instead of the full sweep
/// (`TESTKIT_REPLAY=0xdeadbeef cargo test -q failing_test_name`). Accepts
/// hex (with `0x`) or decimal.
pub const REPLAY_ENV: &str = "TESTKIT_REPLAY";

/// Parse a `TESTKIT_REPLAY` value. Split out of the env read so the
/// parsing is unit-testable without process-global env mutation.
pub fn parse_replay(value: Option<&str>) -> Option<u64> {
    let v = value?.trim();
    if v.is_empty() {
        return None;
    }
    match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => v.parse().ok(),
    }
}

fn replay_from_env() -> Option<u64> {
    parse_replay(std::env::var(REPLAY_ENV).ok().as_deref())
}

fn case_seed(base_seed: u64, case: usize) -> u64 {
    base_seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(case as u64)
}

/// Run `prop` over `n` random cases derived from `base_seed`. Panics with
/// the failing case seed on the first violation. When `TESTKIT_REPLAY` is
/// set, only that seed runs (all `n` sweep cases are skipped).
pub fn check<F>(name: &str, base_seed: u64, n: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Some(seed) = replay_from_env() {
        return replay(name, seed, prop);
    }
    for case in 0..n {
        let case_seed = case_seed(base_seed, case);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{n} \
                 (replay seed: {case_seed:#x} — rerun with \
                 {REPLAY_ENV}={case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn replay<F>(name: &str, case_seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property {name:?} failed on replay {case_seed:#x}: {msg}");
    }
}

/// Shrinking iteration cap — a guard against cyclic shrinkers, far above
/// any honest shrink depth.
const MAX_SHRINK_STEPS: usize = 10_000;

/// Like [`check`], but over explicit case values with minimal-case
/// shrinking: `gen` builds a case from the seeded RNG, `prop` judges it,
/// and on failure `shrink` proposes simpler candidates (typically: halve
/// every size, zero every field — see [`shrink_vec`]/[`shrink_usize`]).
/// The harness greedily walks to a fixed point (no candidate fails any
/// more) and panics reporting the seed *and* the minimal failing case.
/// Honors `TESTKIT_REPLAY` exactly like [`check`].
pub fn check_cases<T, G, S, P>(
    name: &str,
    base_seed: u64,
    n: usize,
    mut gen: G,
    shrink: S,
    mut prop: P,
) where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: FnMut(&T) -> Result<(), String>,
{
    let (cases, replay_only) = match replay_from_env() {
        Some(seed) => (vec![(usize::MAX, seed)], true),
        None => ((0..n).map(|c| (c, case_seed(base_seed, c))).collect(), false),
    };
    for (case, case_seed) in cases {
        let mut rng = Rng::new(case_seed);
        let value = gen(&mut rng);
        if let Err(first_msg) = prop(&value) {
            let (minimal, msg, steps) =
                shrink_to_fixed_point(value, first_msg, &shrink, &mut prop);
            let which = if replay_only {
                format!("replay {case_seed:#x}")
            } else {
                format!(
                    "case {case}/{n} (replay seed: {case_seed:#x} — rerun with \
                     {REPLAY_ENV}={case_seed:#x})"
                )
            };
            panic!(
                "property {name:?} failed on {which}: {msg}\n  minimal case \
                 (after {steps} shrink steps): {minimal:?}"
            );
        }
    }
}

fn shrink_to_fixed_point<T, S, P>(
    mut value: T,
    mut msg: String,
    shrink: &S,
    prop: &mut P,
) -> (T, String, usize)
where
    S: Fn(&T) -> Vec<T>,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut steps = 0;
    'outer: while steps < MAX_SHRINK_STEPS {
        for candidate in shrink(&value) {
            if let Err(m) = prop(&candidate) {
                value = candidate;
                msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break; // fixed point: every candidate passes
    }
    (value, msg, steps)
}

/// Standard shrink candidates for a vector case: empty, first half, all
/// but the last element. Combine with field zeroing in a custom shrinker.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if !v.is_empty() {
        out.push(Vec::new());
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
    }
    out
}

/// Standard shrink candidates for a size/index: zero and the halves.
pub fn shrink_usize(x: usize) -> Vec<usize> {
    match x {
        0 => vec![],
        1 => vec![0],
        _ => vec![0, x / 2, x - 1],
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Random f32 vector in [-scale, scale].
pub fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 1, 25, |rng| {
            count += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always_fails", 2, 5, |_| Err("nope".into()));
    }

    #[test]
    fn parse_replay_forms() {
        assert_eq!(parse_replay(None), None);
        assert_eq!(parse_replay(Some("")), None);
        assert_eq!(parse_replay(Some("42")), Some(42));
        assert_eq!(parse_replay(Some("0x2a")), Some(0x2a));
        assert_eq!(parse_replay(Some("0X2A")), Some(0x2a));
        assert_eq!(parse_replay(Some(" 0xdeadbeef ")), Some(0xdead_beef));
        assert_eq!(parse_replay(Some("nope")), None);
    }

    #[test]
    fn reported_seed_replays_the_same_case() {
        // The panic message promises the seed reproduces the case: the
        // value drawn under the reported seed equals the sweep's draw.
        let mut sweep_draw = 0u64;
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check("pick", 77, 64, |rng| {
                let x = rng.next_u64();
                if x % 3 == 0 {
                    sweep_draw = x;
                    Err("divisible".into())
                } else {
                    Ok(())
                }
            })
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap().clone();
        let hex = msg.split("replay seed: ").nth(1).unwrap();
        let hex = hex.split(|c: char| c == ' ' || c == ')').next().unwrap();
        let failing_case_seed = parse_replay(Some(hex)).unwrap();
        let mut replayed = 0u64;
        let replay_err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            replay("pick", failing_case_seed, |rng| {
                replayed = rng.next_u64();
                Err("stop".into())
            })
        }));
        assert!(replay_err.is_err());
        assert_eq!(replayed, sweep_draw, "replay must regenerate the case");
    }

    #[test]
    fn check_cases_shrinks_to_minimal() {
        // Property: vectors shorter than 3 pass. The generator draws much
        // longer vectors; shrinking must land exactly on length 3.
        let err = std::panic::catch_unwind(|| {
            check_cases(
                "min3",
                5,
                10,
                |rng| (0..(3 + rng.usize_below(40))).map(|i| i as u32).collect::<Vec<u32>>(),
                |v: &Vec<u32>| shrink_vec(v.as_slice()),
                |v| {
                    if v.len() < 3 {
                        Ok(())
                    } else {
                        Err(format!("len {}", v.len()))
                    }
                },
            )
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("minimal case"), "{msg}");
        assert!(msg.contains("[0, 1, 2]"), "must shrink to the 3-element floor: {msg}");
        assert!(msg.contains("TESTKIT_REPLAY"), "{msg}");
    }

    #[test]
    fn check_cases_passes_without_shrinking() {
        let mut ran = 0;
        check_cases(
            "always_ok",
            9,
            12,
            |rng| rng.usize_below(100),
            |&x| shrink_usize(x),
            |_| {
                ran += 1;
                Ok(())
            },
        );
        assert_eq!(ran, 12);
    }

    #[test]
    fn shrink_helpers_shapes() {
        assert!(shrink_vec::<u8>(&[]).is_empty());
        assert_eq!(shrink_vec(&[1]), vec![Vec::<i32>::new()]);
        assert_eq!(shrink_vec(&[1, 2, 3, 4]), vec![vec![], vec![1, 2], vec![1, 2, 3]]);
        assert!(shrink_usize(0).is_empty());
        assert_eq!(shrink_usize(1), vec![0]);
        assert_eq!(shrink_usize(10), vec![0, 5, 9]);
    }

    #[test]
    fn shrink_fixed_point_terminates_on_cyclic_shrinker() {
        // A shrinker that always re-proposes a failing candidate must be
        // stopped by the step cap, not loop forever.
        let (v, _msg, steps) = shrink_to_fixed_point(
            1usize,
            "seed".into(),
            &|&x: &usize| vec![x],     // proposes itself forever
            &mut |_: &usize| Err("still failing".into()),
        );
        assert_eq!(v, 1);
        assert_eq!(steps, MAX_SHRINK_STEPS);
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.00001], 1e-3).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }

    #[test]
    fn rand_vec_in_range() {
        let mut rng = Rng::new(3);
        let v = rand_vec(&mut rng, 100, 2.0);
        assert!(v.iter().all(|&x| (-2.0..=2.0).contains(&x)));
    }
}

/// Allocation counting for hot-path "does not allocate" assertions.
///
/// [`alloc_counter::CountingAlloc`] is a [`std::alloc::System`] wrapper that
/// counts allocations (and reallocations) per thread. It does nothing until
/// a test binary installs it:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: photon::testkit::alloc_counter::CountingAlloc =
///     photon::testkit::alloc_counter::CountingAlloc;
/// ```
///
/// after which [`alloc_counter::count`] brackets a closure and reports how
/// many heap allocations it performed on the current thread. The zero-copy
/// frame tests in `rust/tests/props_perf.rs` use this to prove the codec
/// `none` decode path borrows instead of copying. Deallocations are not
/// counted — freeing is allowed on a "no new allocations" hot path.
pub mod alloc_counter {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    thread_local! {
        // `const` init: no lazy-init allocation, no TLS destructor — safe
        // to touch from inside the global allocator itself.
        static ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    /// Counting wrapper around the system allocator. Zero-sized; install
    /// with `#[global_allocator]` in the test binary that needs counts.
    pub struct CountingAlloc;

    // SAFETY: pure delegation to `System`; the per-thread counter bump
    // cannot allocate (const-initialised TLS) or unwind.
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.with(|c| c.set(c.get() + 1));
            unsafe { System.alloc(layout) }
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            unsafe { System.dealloc(ptr, layout) }
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            // A grow/shrink is a fresh acquisition for counting purposes.
            ALLOCS.with(|c| c.set(c.get() + 1));
            unsafe { System.realloc(ptr, layout, new_size) }
        }

        // alloc_zeroed is NOT overridden: the default forwards to `alloc`,
        // so zeroed allocations (`vec![0u8; n]`) are counted too.
    }

    /// Total allocations observed on this thread since it started (always 0
    /// unless [`CountingAlloc`] is the installed global allocator).
    pub fn allocs_on_this_thread() -> u64 {
        ALLOCS.with(|c| c.get())
    }

    /// Run `f` and return its result plus the number of heap allocations it
    /// performed on this thread.
    pub fn count<T>(f: impl FnOnce() -> T) -> (T, u64) {
        let before = allocs_on_this_thread();
        let out = f();
        (out, allocs_on_this_thread() - before)
    }
}

#[cfg(test)]
mod alloc_counter_tests {
    use super::alloc_counter;

    // The lib test binary does not install CountingAlloc, so counts stay 0;
    // the real non-zero assertions live in rust/tests/props_perf.rs, which
    // does install it. Here we pin the API contract that holds either way.
    #[test]
    fn count_is_monotonic_and_count_never_goes_negative() {
        let a = alloc_counter::allocs_on_this_thread();
        let (v, n) = alloc_counter::count(|| vec![1u8, 2, 3]);
        assert_eq!(v, vec![1, 2, 3]);
        let b = alloc_counter::allocs_on_this_thread();
        assert!(b >= a);
        assert_eq!(n, b - a);
    }
}
