//! `photon` — leader entrypoint + CLI for the Photon-RS federated LLM
//! pre-training system.
//!
//! ```text
//! photon list                              available experiments & models
//! photon exp <id> [--fast|--paper-scale] [--rounds N] [--steps N] [--seed S]
//! photon exp wallclock [--size 125M] [--taus 50,500] [--policy all|sync|semisync|overlap]
//!              [--clients P] [--sampled K] [--straggler p] [--dropout p]
//!              [--slowdown x] [--deadline f] [--mfu u]
//! photon exp distributed [--fleet W]       TCP fleet vs in-process parity sweep
//! photon train --config m350a [--clients P] [--sampled K] [--rounds N]
//!              [--steps T] [--outer fedavg|sgdn|fedadam|...] [--hetero]
//!              [--keep-opt] [--dropout p] [--straggler p]
//!              [--ckpt-dir DIR] [--resume] [--lr-max X] [--fleet-hetero]
//!              [--workers N|auto] [--parallel-dispatch]
//!              [--codec none|deflate|q8[:block]|q4[:block]|topk[:permille]]
//! photon serve [same training flags] [--bind 0.0.0.0:7070] [--min-workers K]
//!              [--deadline-secs F] [--stall-secs F] [--migrate]
//!              [--no-compress] [--codec q8] [--event-log LOG]
//!              [--async-agg K[:gamma]]
//!              run the Aggregator as a TCP service (deployment plane);
//!              --migrate reassigns a dead/silent worker's unstarted
//!              clients to live workers before the deadline cut;
//!              --async-agg drops the round barrier and folds the first
//!              K arrivals per epoch at staleness discount γ
//! photon exp chaos [--fleet W] [--rates 0,15,30,45] [--deadline-secs F]
//!              seeded chaos sweep: fault rate × lease migration, with
//!              bit-exact trace replay and sim-priced churn
//! photon exp async [--fleet W] [--fold-k K] [--gammas 1.0,0.5]
//!              [--rates 0,25] [--taus T1,T2] [--deadline-secs F]
//!              buffered async sweep: staleness discount γ × fault rate × τ,
//!              every fleet bit-equals its ledger replay
//! photon worker --connect HOST:7070 [--name NAME]
//!              run one LLM Node worker against a remote Aggregator
//! photon subagg --upstream HOST:7070 [--bind 0.0.0.0:7071] [--name NAME]
//!              [--min-workers K] [--deadline-secs F]
//!              run a mid-tier sub-aggregator: leases a slice of each
//!              sampled cohort from a tree-mode root (`serve --tiers T`),
//!              re-leases it to downstream workers, folds their updates
//!              locally, pushes one pre-folded pair upstream
//! photon eval --config m350a               downstream ICL suite on a fresh init
//! photon info [--config NAME]              artifact inventory
//! photon top --follow LOG | --replay LOG [--until-seq N] [--stats]
//!              terminal cockpit over a structured JSONL event log
//!              (--event-log on serve/train/worker writes one); --replay
//!              renders deterministically, --stats prints a summary
//! photon evck FILE...
//!              validate structured JSONL event logs against the obs
//!              schema (consecutive seq, known kinds — docs/OBSERVABILITY.md)
//! photon lint [--src DIR] [--explain RULE]
//!              determinism & concurrency static analysis over rust/src
//!              (nondet-map, nondet-time, nondet-rng, wire-panic,
//!              wire-alloc, lock-order, allow-policy — see docs/ANALYSIS.md)
//! photon benchck FILE...
//!              validate BENCH_*.json perf snapshots against the benchkit
//!              record schema (CI gates the committed baselines with this
//!              before tools/bench_compare.py diffs them)
//! ```

use anyhow::{bail, Result};

use photon::cluster::faults::FaultPlan;
use photon::cluster::hardware::FleetSpec;
use photon::compress::UpdateCodec;
use photon::config::{CorpusKind, ExecConfig, ExperimentConfig, OptStatePolicy};
use photon::coordinator::Federation;
use photon::exp;
use photon::net::{run_worker, ServeOpts, Server, WorkerOpts};
use photon::optim::outer::{OuterHyper, OuterOptKind};
use photon::optim::schedule::CosineSchedule;
use photon::util::cli::{Args, Spec};

const SPEC: Spec = Spec {
    options: &[
        "config", "rounds", "steps", "seed", "clients", "sampled", "outer",
        "server-lr", "server-momentum", "lr-max", "eval-batches", "dropout",
        "straggler", "ckpt-dir", "j", "items", "workers",
        // wall-clock simulation (exp wallclock)
        "size", "taus", "policy", "deadline", "slowdown", "mfu",
        // deployment plane (serve / worker / exp distributed)
        "bind", "connect", "name", "deadline-secs", "min-workers", "fleet",
        // observability plane (serve / train / worker / top / evck)
        "stall-secs", "event-log", "follow", "replay", "until-seq",
        // update-codec plane (train / serve / exp comm|distributed|wallclock)
        "codec",
        // aggregation-tree plane (train / serve / subagg)
        "tiers", "upstream", "state-budget",
        // resilience plane (exp chaos)
        "rates",
        // async aggregation plane (serve / exp async)
        "async-agg", "fold-k", "gammas",
        // static-analysis plane (lint)
        "src", "explain",
    ],
    flags: &[
        "fast", "paper-scale", "hetero", "mc4", "keep-opt", "resume",
        "fleet-hetero", "verbose", "parallel-dispatch", "no-compress",
        // resilience plane (serve / exp chaos): mid-round client-lease
        // migration off a dead or silent worker (needs --deadline-secs)
        "migrate",
        // observability plane (top): print the two-line summary instead
        // of the full cockpit frame
        "stats",
    ],
};

fn usage() -> &'static str {
    "usage: photon <list|exp|train|serve|worker|subagg|eval|info|top|evck|lint|benchck> [args]\n  try: photon list"
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, &SPEC)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "list" => cmd_list(),
        "exp" => {
            let id = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("exp needs an id (see `photon list`)"))?;
            exp::run(id, &args)
        }
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "subagg" => cmd_subagg(&args),
        "eval" => cmd_eval(&args),
        "info" => cmd_info(&args),
        "top" => cmd_top(&args),
        "evck" => cmd_evck(&args),
        "lint" => cmd_lint(&args),
        "benchck" => cmd_benchck(&args),
        "help" | "--help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn cmd_list() -> Result<()> {
    println!("experiments (photon exp <id>):");
    for e in &exp::EXPERIMENTS {
        println!("  {:<8} {}", e.id, e.what);
    }
    println!("\nmodel configs (photon train --config <name>):");
    let idx = photon::util::artifacts_dir().join("index.json");
    match photon::util::json::Json::parse_file(&idx) {
        Ok(v) => {
            for c in v.get("configs")?.as_arr()? {
                let name = c.as_str()?;
                match photon::model::manifest::Manifest::load(
                    &photon::util::artifacts_dir().join(name),
                ) {
                    Ok(m) => println!(
                        "  {:<12} {:>9} params  (analogue of {})",
                        name, m.n_params, m.config.paper_alias
                    ),
                    Err(_) => println!("  {name:<12} (manifest unreadable)"),
                }
            }
        }
        Err(_) => println!("  (no artifacts — run `make artifacts`)"),
    }
    Ok(())
}

/// Build the federated config shared by `train` and `serve` from the CLI
/// flags (same flags, same defaults — a `serve` run with the flags of a
/// `train` run executes the identical federation, just over TCP).
fn train_config(args: &Args, label_prefix: &str) -> Result<ExperimentConfig> {
    let model = args.get_or("config", "m75a");
    let p = args.get_usize("clients", 8)?;
    let k = args.get_usize("sampled", p)?;
    let rounds = args.get_usize("rounds", 10)?;
    let steps = args.get_u64("steps", 40)?;
    let seed = args.get_u64("seed", 42)?;
    let total = rounds as u64 * steps;

    let corpus = if args.flag("hetero") {
        CorpusKind::PileHetero { j: args.get_usize("j", 1)? }
    } else if args.flag("mc4") {
        CorpusKind::Mc4 { n_langs: 4 }
    } else {
        CorpusKind::C4Iid
    };

    Ok(ExperimentConfig {
        label: format!("{label_prefix}-{model}"),
        model,
        corpus,
        n_clients: p,
        clients_per_round: k,
        rounds,
        local_steps: steps,
        seed,
        outer: OuterOptKind::parse(&args.get_or("outer", "fedavg"))?,
        outer_hyper: OuterHyper {
            lr: args.get_f64("server-lr", 1.0)?,
            momentum: args.get_f64("server-momentum", 0.9)?,
            ..OuterHyper::default()
        },
        schedule: CosineSchedule::new(
            args.get_f64("lr-max", 3e-3)?,
            0.1,
            total.max(2),
            (total / 20).min(100),
        ),
        opt_state: if args.flag("keep-opt") {
            OptStatePolicy::KeepOpt
        } else {
            OptStatePolicy::Stateless
        },
        eval_batches: args.get_usize("eval-batches", 4)?,
        faults: FaultPlan::new(
            args.get_f64("dropout", 0.0)?,
            args.get_f64("straggler", 0.0)?,
            seed,
        ),
        fleet: if args.flag("fleet-hetero") {
            Some(FleetSpec::heterogeneous(p))
        } else {
            None
        },
        exec: ExecConfig {
            workers: args.get_count_or_auto("workers", 1)?,
            serialize_dispatch: !args.flag("parallel-dispatch"),
        },
        codec: UpdateCodec::parse(&args.get_or("codec", "none"))?,
        tiers: args.get_usize("tiers", 1)?,
    })
}

/// Apply `--ckpt-dir` / `--resume` to a freshly built federation.
fn apply_ckpt_flags(args: &Args, fed: &mut Federation) -> Result<()> {
    if let Some(dir) = args.get("ckpt-dir") {
        let dir = std::path::PathBuf::from(dir);
        fed.ckpt_dir = Some(dir.clone());
        if args.flag("resume") && fed.try_resume_from(&dir)? {
            println!("[resume] continuing from round {}", fed.next_round);
        }
    }
    Ok(())
}

/// Build the `--event-log` sink shared by `train`, `serve`, and `worker`:
/// a structured JSONL event stream for `photon top` / `photon evck`.
fn event_log_flag(args: &Args) -> Result<Option<photon::obs::EventSink>> {
    match args.get("event-log") {
        Some(p) => {
            let path = std::path::Path::new(p);
            let sink = photon::obs::EventSink::to_file(path)?;
            println!("[obs] writing event log to {}", path.display());
            Ok(Some(sink))
        }
        None => Ok(None),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = train_config(args, "train")?;
    let model = cfg.model.clone();
    let (p, k, rounds, steps) =
        (cfg.n_clients, cfg.clients_per_round, cfg.rounds, cfg.local_steps);
    let mut fed = Federation::new(cfg)?;
    apply_ckpt_flags(args, &mut fed)?;
    fed.obs = event_log_flag(args)?;

    let workers = match fed.cfg.exec.workers {
        0 => "auto".to_string(),
        w => w.to_string(),
    };
    println!(
        "training {model}: P={p} K={k} rounds={rounds} τ={steps} outer={:?} \
         workers={workers} codec={}",
        fed.cfg.outer,
        fed.cfg.codec.label(),
    );
    while fed.next_round < fed.cfg.rounds {
        let r = fed.run_round()?;
        println!(
            "round {:>3}  server_ppl {:>9.3}  client_loss {:>7.4} ±{:<7.4} \
             pseudo|Δ| {:>8.4}  participated {}/{}  {:.2}s",
            r.round, r.server_ppl, r.client_loss_mean, r.client_loss_std,
            r.pseudo_grad_norm, r.participated, fed.cfg.clients_per_round,
            r.wall_secs,
        );
    }
    let out = photon::util::results_dir("train").join(format!("{model}.csv"));
    fed.log.write_csv(&out)?;
    println!("wrote {}", out.display());
    Ok(())
}

/// Parse `--async-agg K[:gamma]` (e.g. `3` or `3:0.5`; γ defaults to 0.5,
/// matching the sim policy spelling `async[:K[:gamma]]`).
fn parse_async_agg(v: Option<&str>) -> Result<Option<(usize, f64)>> {
    let Some(v) = v else { return Ok(None) };
    let (k_tok, gamma_tok) = match v.split_once(':') {
        Some((k, g)) => (k, Some(g)),
        None => (v, None),
    };
    let k: usize = k_tok
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("--async-agg expects K[:gamma], got {v:?}"))?;
    let gamma: f64 = match gamma_tok {
        None => 0.5,
        Some(g) => g
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("--async-agg expects K[:gamma], got {v:?}"))?,
    };
    Ok(Some((k, gamma)))
}

/// `photon serve`: run the Aggregator as a TCP service (deployment plane).
/// Same training flags as `photon train`; identical config + seed produces
/// a bit-identical run, just executed by remote workers.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = train_config(args, "serve")?;
    let model = cfg.model.clone();
    let mut min_workers = args.get_usize("min-workers", 1)?;
    // A tree round needs one live sub-aggregator per tier group or it
    // stalls out the whole join timeout every round; refuse to start
    // under-provisioned rather than hang.
    let tier_groups = cfg.tiers.min(cfg.clients_per_round);
    if cfg.tiers > 1 && min_workers < tier_groups {
        println!(
            "[serve] tiers = {} with clients_per_round = {} makes up to {} \
             group(s) per round; raising min-workers {} -> {}",
            cfg.tiers, cfg.clients_per_round, tier_groups, min_workers, tier_groups,
        );
        min_workers = tier_groups;
    }
    let opts = ServeOpts {
        bind: args.get_or("bind", "127.0.0.1:7070"),
        min_workers,
        deadline_secs: match args.get_f64("deadline-secs", 0.0)? {
            x if x > 0.0 => Some(x),
            _ => None,
        },
        migrate: args.flag("migrate"),
        compress: !args.flag("no-compress"),
        stall_secs: args.get_f64("stall-secs", 3600.0)?,
        state_budget: match args.get_u64("state-budget", 0)? {
            0 => None,
            b => Some(b),
        },
        async_agg: parse_async_agg(args.get("async-agg"))?,
        ..ServeOpts::default()
    };
    let mut fed = Federation::new(cfg)?;
    apply_ckpt_flags(args, &mut fed)?;
    fed.obs = event_log_flag(args)?;
    let mut server = Server::with_federation(fed, opts)?;
    println!(
        "[serve] aggregator for {model} listening on {} (waiting for {} workers; \
         deadline {:?})",
        server.local_addr(),
        min_workers,
        args.get("deadline-secs").unwrap_or("none"),
    );
    server.run()?;
    if !server.cuts.is_empty() {
        println!("[serve] realized straggler/crash cuts: {:?}", server.cuts);
    }
    let trace = server.trace();
    if trace.total_migrated() + trace.total_rejoined() > 0 {
        println!(
            "[serve] elastic events: {} lease migration(s), {} worker rejoin(s)",
            trace.total_migrated(),
            trace.total_rejoined()
        );
    }
    let out = photon::util::results_dir("serve").join(format!("{model}.csv"));
    server.federation().log.write_csv(&out)?;
    println!("wrote {}", out.display());
    Ok(())
}

/// `photon worker`: one LLM Node executor serving a remote Aggregator.
fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.require("connect")?;
    let name = args.get_or("name", &format!("worker-{}", std::process::id()));
    let obs = event_log_flag(args)?;
    let report = run_worker(
        addr,
        WorkerOpts { name, obs, verbose: true, ..WorkerOpts::default() },
    )?;
    println!(
        "[worker] session over: slot {}, {} rounds served, {} updates pushed",
        report.worker_slot, report.rounds_served, report.updates_pushed
    );
    Ok(())
}

/// `photon subagg`: mid-tier sub-aggregator between a tree-mode root
/// Aggregator (`serve --tiers T`, T > 1) and downstream workers. Joins
/// the root as one worker slot, leases a slice of each sampled cohort,
/// re-leases it to its own workers, and pushes one pre-folded
/// `(weight, mean)` pair upstream per round.
fn cmd_subagg(args: &Args) -> Result<()> {
    use photon::net::{run_subagg, SubaggOpts};
    let upstream = args.require("upstream")?;
    let opts = SubaggOpts {
        name: args.get_or("name", &format!("subagg-{}", std::process::id())),
        bind: args.get_or("bind", "127.0.0.1:0"),
        min_workers: args.get_usize("min-workers", 1)?,
        deadline_secs: match args.get_f64("deadline-secs", 0.0)? {
            x if x > 0.0 => Some(x),
            _ => None,
        },
        stall_secs: args.get_f64("stall-secs", 3600.0)?,
        verbose: true,
        ..SubaggOpts::default()
    };
    let report = run_subagg(upstream, opts, None)?;
    println!(
        "[subagg] session over: {} round(s) folded upstream, {} member update(s), \
         {} worker connection(s)",
        report.rounds_served, report.members_folded, report.workers_admitted
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get_or("config", "m75a");
    let n_items = args.get_usize("items", 30)?;
    let rt = photon::runtime::Runtime::cpu()?;
    let m = rt.load_model(&model)?;
    let params = photon::model::init::init_params(&m.manifest, args.get_u64("seed", 42)?);
    let corpus =
        photon::data::corpus::SyntheticCorpus::pile(m.manifest.config.vocab);
    let fams = photon::evalharness::TaskFamily::suite(&corpus, m.manifest.config.seq_len);
    println!("ICL suite on {model} (fresh init — expect chance-level):");
    for f in &fams {
        let acc = photon::evalharness::task_accuracy(&m, &params, &corpus, f, n_items, 7)?;
        println!("  {:<24} {:.3}  (chance {:.3})", f.name, acc, 1.0 / f.n_options as f64);
    }
    Ok(())
}

/// `photon top`: terminal cockpit over a structured JSONL event log
/// (see docs/OBSERVABILITY.md). `--follow LOG` tails a live file and
/// redraws until a `shutdown` event lands; `--replay LOG` reduces the log
/// once (bounded by `--until-seq N`) and renders the final frame — a pure
/// function of the bytes, so two replays of one log are byte-identical.
/// `--stats` swaps the frame for a two-line grep-able summary.
fn cmd_top(args: &Args) -> Result<()> {
    use photon::obs;
    if let Some(path) = args.get("replay") {
        let until = args.get_u64("until-seq", u64::MAX)?;
        let (records, skipped) = obs::read_log(std::path::Path::new(path))?;
        let mut view = obs::ViewState::default();
        for rec in &records {
            if rec.seq > until {
                break;
            }
            view.apply(rec);
        }
        if skipped > 0 {
            eprintln!("[top] {skipped} unparsable line(s) skipped");
        }
        if args.flag("stats") {
            print!("{}", obs::render_stats(&view));
        } else {
            print!("{}", obs::render_frame(&view, obs::Mode::Replay));
        }
        return Ok(());
    }
    let path = args.require("follow").map_err(|_| {
        anyhow::anyhow!("top needs --follow LOG or --replay LOG (a JSONL event log)")
    })?;
    let mut tail = obs::Tail::open(std::path::Path::new(path))?;
    let mut view = obs::ViewState::default();
    loop {
        for rec in &tail.poll()? {
            view.apply(rec);
        }
        if args.flag("stats") {
            print!("{}", obs::render_stats(&view));
            return Ok(());
        }
        print!("{}{}", obs::CLEAR, obs::render_frame(&view, obs::Mode::Live));
        use std::io::Write;
        std::io::stdout().flush().ok();
        if view.shutdown {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(250));
    }
}

/// `photon evck FILE...`: validate structured JSONL event logs against the
/// obs schema — every line a known event kind with its required fields,
/// `seq` strictly consecutive from 0 (`ts_us` is deliberately unchecked:
/// wall clocks step). CI runs this over a freshly produced harness log so
/// the schema in docs/OBSERVABILITY.md cannot drift from the emitters.
#[allow(clippy::disallowed_methods)] // wall-clock timing is reporting-only here
fn cmd_evck(args: &Args) -> Result<()> {
    let files = &args.positional[1..];
    if files.is_empty() {
        bail!("evck needs at least one event-log (.jsonl) path");
    }
    let t0 = std::time::Instant::now();
    let mut total = 0usize;
    for f in files {
        let path = std::path::Path::new(f);
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let n = photon::obs::validate_log_text(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e:#}", path.display()))?;
        println!("[evck] {}: {} event(s) ok", path.display(), n);
        total += n;
    }
    println!("[evck] {} file(s), {} event(s), schema ok", files.len(), total);
    photon::obs::timing("evck", "schema check", t0.elapsed().as_secs_f64());
    Ok(())
}

/// `photon lint`: the determinism & concurrency static-analysis plane.
/// Walks the source tree, runs every rule (see docs/ANALYSIS.md), prints
/// `file:line [rule] message` per violation plus the lock-acquisition
/// graph summary, and exits non-zero if anything survives suppression.
#[allow(clippy::disallowed_methods)] // wall-clock timing is reporting-only here
fn cmd_lint(args: &Args) -> Result<()> {
    use photon::analysis;
    if let Some(rule) = args.get("explain") {
        return match analysis::explain::explain(rule) {
            Some(text) => {
                println!("{text}");
                Ok(())
            }
            None => {
                let known: Vec<&str> = analysis::RULES.iter().map(|(r, _)| *r).collect();
                bail!("unknown rule {rule:?}; known rules: {}", known.join(", "))
            }
        };
    }
    let root = match args.get("src") {
        Some(p) => std::path::PathBuf::from(p),
        None => ["rust/src", "src"]
            .iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.join("lib.rs").is_file())
            .ok_or_else(|| {
                anyhow::anyhow!("cannot find a source root (rust/src or src); pass --src DIR")
            })?,
    };
    let t0 = std::time::Instant::now();
    let report = analysis::lint_tree(&root)?;
    for d in &report.diagnostics {
        println!("{d}");
    }
    println!("{}", report.locks.summary());
    for e in &report.locks.edges {
        println!("  {} → {} (first at {}:{})", e.from, e.to, e.file, e.line);
    }
    println!(
        "[lint] {} file(s) under {}, {} violation(s)",
        report.files,
        root.display(),
        report.diagnostics.len(),
    );
    photon::obs::timing("lint", "tree scan", t0.elapsed().as_secs_f64());
    if !report.diagnostics.is_empty() {
        bail!(
            "{} lint violation(s) — `photon lint --explain <rule>` documents the \
             contract behind each rule",
            report.diagnostics.len(),
        );
    }
    Ok(())
}

/// `photon benchck FILE...`: validate perf snapshots against the benchkit
/// record schema (array of `{bench, iters, mean_ns, p50_ns, p95_ns,
/// units_per_sec, git_rev}` with unique names and finite positive timings).
/// CI runs this over the committed `BENCH_*.json` baselines and the freshly
/// emitted ones before `tools/bench_compare.py` diffs the pair.
#[allow(clippy::disallowed_methods)] // wall-clock timing is reporting-only here
fn cmd_benchck(args: &Args) -> Result<()> {
    let files = &args.positional[1..];
    if files.is_empty() {
        bail!("benchck needs at least one BENCH_*.json path");
    }
    let t0 = std::time::Instant::now();
    let mut total = 0usize;
    for f in files {
        let path = std::path::Path::new(f);
        let v = photon::util::json::Json::parse_file(path)?;
        let n = photon::benchkit::validate_snapshot(&v)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        println!("[benchck] {}: {} record(s) ok", path.display(), n);
        total += n;
    }
    println!("[benchck] {} file(s), {} record(s), schema ok", files.len(), total);
    photon::obs::timing("benchck", "schema check", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    match args.get("config") {
        None => cmd_list(),
        Some(name) => {
            let m = photon::model::manifest::Manifest::load(
                &photon::util::artifacts_dir().join(name),
            )?;
            println!("config {name} (analogue of {})", m.config.paper_alias);
            println!(
                "  vocab {}  d_model {}  heads {}  blocks {}  seq {}  batch {}  attn {}",
                m.config.vocab, m.config.d_model, m.config.n_heads,
                m.config.n_blocks, m.config.seq_len, m.config.batch_size,
                m.config.attn_impl
            );
            println!("  {} params ({} tensors, {} payload)",
                m.n_params, m.params.len(), m.payload_bytes());
            for p in &m.params {
                println!("    {:<16} {:?} @ {}", p.name, p.shape, p.offset);
            }
            Ok(())
        }
    }
}
