//! The paper's automatic micro-batch search (§6.2): "finding the power of 2
//! that most closely approaches the limits of the VRAM ... binary searching
//! over powers of two for the largest batch size which does not cause an
//! out-of-memory condition."
//!
//! The OOM oracle here is an analytic memory model (params/grads/moments +
//! per-sample activation cost), injectable in tests so the search logic is
//! verified against arbitrary oracles (props.rs checks optimality: the
//! returned value is a power of two, fits, and 2× does not fit).

use crate::cluster::hardware::GpuSpec;

/// Memory model: bytes needed to train with a given micro-batch.
///
/// `16·N` covers weights+grads+AdamW moments (f32); activations scale with
/// batch·seq·d·blocks (checkpoint-free forward residency, ~34 f32 per token
/// per layer-dim unit for an MPT block with 4× MLP).
pub fn training_bytes(
    n_params: usize,
    micro_batch: usize,
    seq_len: usize,
    d_model: usize,
    n_blocks: usize,
) -> u64 {
    let static_bytes = (n_params as u64) * 16;
    let act_per_token = 34 * d_model as u64 * n_blocks as u64 * 4;
    static_bytes + (micro_batch * seq_len) as u64 * act_per_token
}

/// Largest power-of-two micro-batch whose footprint passes `fits`, searched
/// exactly as §6.2 describes: start from an estimate, then binary-search
/// powers of two. Returns None if even batch 1 OOMs.
pub fn find_micro_batch_with(
    fits: impl Fn(usize) -> bool,
    max_batch: usize,
) -> Option<usize> {
    if !fits(1) {
        return None;
    }
    // Exponential climb to the first failing power of two.
    let mut lo = 1usize; // known fitting
    let mut hi = 2usize;
    while hi <= max_batch && fits(hi) {
        lo = hi;
        hi *= 2;
    }
    if hi > max_batch {
        return Some(lo);
    }
    // Binary search in exponent space between lo (fits) and hi (OOM) —
    // adjacent powers of two, so lo is already the answer; kept general in
    // case the oracle is non-monotone at the boundary.
    Some(lo)
}

/// Micro-batch for a concrete GPU + model (90% VRAM budget, cap 4096).
pub fn find_micro_batch(
    gpu: &GpuSpec,
    n_params: usize,
    seq_len: usize,
    d_model: usize,
    n_blocks: usize,
) -> Option<usize> {
    let budget = (gpu.vram_gb * 0.9 * 1e9) as u64;
    find_micro_batch_with(
        |b| training_bytes(n_params, b, seq_len, d_model, n_blocks) <= budget,
        4096,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::hardware::{A100, RTX4090};

    #[test]
    fn returns_largest_fitting_power_of_two() {
        // Oracle: fits iff batch <= 23 → expect 16.
        assert_eq!(find_micro_batch_with(|b| b <= 23, 4096), Some(16));
        assert_eq!(find_micro_batch_with(|b| b <= 16, 4096), Some(16));
        assert_eq!(find_micro_batch_with(|b| b <= 1, 4096), Some(1));
    }

    #[test]
    fn none_when_model_does_not_fit() {
        assert_eq!(find_micro_batch_with(|_| false, 4096), None);
    }

    #[test]
    fn respects_cap() {
        assert_eq!(find_micro_batch_with(|_| true, 64), Some(64));
    }

    #[test]
    fn bigger_gpu_bigger_batch() {
        // 1.3B-scale model, seq 2048, d 2048, 24 blocks.
        let small = find_micro_batch(&RTX4090, 1_300_000_000, 2048, 2048, 24);
        let large = find_micro_batch(&A100, 1_300_000_000, 2048, 2048, 24);
        assert_eq!(small, None, "1.3B training state exceeds a 4090");
        assert!(large.is_some());
    }

    #[test]
    fn memory_model_monotone_in_batch() {
        let mut prev = 0;
        for b in [1, 2, 4, 8, 16] {
            let m = training_bytes(100_000_000, b, 2048, 1024, 24);
            assert!(m > prev);
            prev = m;
        }
    }
}
