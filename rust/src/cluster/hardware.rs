//! GPU catalog and client hardware descriptions (the paper's fleets mix
//! A40/A100/H100 across countries, §6.5), plus the local-training strategy
//! selection of Algorithm 1 L.14–22 / §5.1.

/// A hardware accelerator model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    pub vram_gb: f64,
    /// Dense f16/bf16 throughput (TFLOP/s) — wall-clock simulation input.
    pub tflops: f64,
}

pub const A40: GpuSpec = GpuSpec { name: "A40", vram_gb: 48.0, tflops: 150.0 };
pub const A100: GpuSpec = GpuSpec { name: "A100", vram_gb: 80.0, tflops: 312.0 };
pub const H100: GpuSpec = GpuSpec { name: "H100", vram_gb: 80.0, tflops: 990.0 };
pub const RTX4090: GpuSpec = GpuSpec { name: "RTX4090", vram_gb: 24.0, tflops: 165.0 };

/// One machine: identical GPUs + intra-node interconnect.
#[derive(Clone, Copy, Debug)]
pub struct NodeSpec {
    pub gpu: GpuSpec,
    pub n_gpus: usize,
    /// Intra-node GPU↔GPU bandwidth (GB/s); NVLink ≈ 600, PCIe ≈ 32.
    pub intra_gbps: f64,
}

/// One client's machines + inter-node connectivity.
#[derive(Clone, Debug)]
pub struct ClientHardware {
    pub nodes: Vec<NodeSpec>,
    /// Inter-node bandwidth (GB/s); Infiniband NDR ≈ 50, WAN ≈ 0.1.
    pub inter_gbps: f64,
}

/// Bandwidth above which nodes count as "well-connected" (Infiniband-class,
/// §5.1: "cannot match the speed of high-bandwidth interconnection such as
/// Infiniband NDR or RoCEv2").
pub const INFINIBAND_GBPS: f64 = 25.0;

/// Local training strategy chosen by a Photon LLM Node (§5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainStrategy {
    SingleGpu,
    /// DDP across all GPUs of one well-connected group.
    Ddp { n_gpus: usize },
    /// FSDP (model too big for one GPU) across a well-connected group.
    Fsdp { n_gpus: usize },
    /// Poorly-connected nodes → per-island sub-federation with partial
    /// aggregation (Algorithm 1 L.19–24).
    SubFederation { islands: usize },
}

impl ClientHardware {
    /// A uniform single-node client.
    pub fn single(gpu: GpuSpec, n_gpus: usize) -> ClientHardware {
        ClientHardware {
            nodes: vec![NodeSpec { gpu, n_gpus, intra_gbps: 600.0 }],
            inter_gbps: INFINIBAND_GBPS,
        }
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes.iter().map(|n| n.n_gpus).sum()
    }

    pub fn has_infiniband(&self) -> bool {
        self.nodes.len() <= 1 || self.inter_gbps >= INFINIBAND_GBPS
    }

    /// Algorithm 1 L.14–22: pick the local execution strategy given the
    /// model's memory demand.
    ///
    /// `model_bytes_per_replica` is the full training-state footprint
    /// (params + grads + AdamW moments + headroom); a replica fits a GPU if
    /// it is under ~90% of VRAM.
    pub fn choose_strategy(&self, model_bytes_per_replica: u64) -> TrainStrategy {
        let fits_one_gpu = |gpu: &GpuSpec| {
            model_bytes_per_replica as f64 <= 0.9 * gpu.vram_gb * 1e9
        };
        if !self.has_infiniband() {
            return TrainStrategy::SubFederation { islands: self.nodes.len() };
        }
        let n = self.total_gpus();
        if n == 1 {
            return TrainStrategy::SingleGpu;
        }
        // Well-connected multi-GPU: DDP if a replica fits, else FSDP.
        if self.nodes.iter().all(|node| fits_one_gpu(&node.gpu)) {
            TrainStrategy::Ddp { n_gpus: n }
        } else {
            TrainStrategy::Fsdp { n_gpus: n }
        }
    }
}

/// Per-client hardware for a whole federation.
#[derive(Clone, Debug)]
pub struct FleetSpec {
    pub clients: Vec<ClientHardware>,
}

impl FleetSpec {
    /// The paper's heterogeneous fleet flavor: cycle A40/A100/H100 singles.
    pub fn heterogeneous(n_clients: usize) -> FleetSpec {
        let gpus = [A40, A100, H100];
        FleetSpec {
            clients: (0..n_clients)
                .map(|i| ClientHardware::single(gpus[i % 3], 1 + (i % 4)))
                .collect(),
        }
    }

    pub fn uniform(n_clients: usize, gpu: GpuSpec, n_gpus: usize) -> FleetSpec {
        FleetSpec {
            clients: (0..n_clients)
                .map(|_| ClientHardware::single(gpu, n_gpus))
                .collect(),
        }
    }
}

/// Training-state bytes for a model of `n_params` f32 parameters:
/// weights + grads + 2 AdamW moments (16 B/param) + 25% activation headroom.
pub fn training_footprint_bytes(n_params: usize) -> u64 {
    (n_params as u64) * 16 * 5 / 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_gpu_strategy() {
        let hw = ClientHardware::single(A100, 1);
        assert_eq!(hw.choose_strategy(1 << 30), TrainStrategy::SingleGpu);
    }

    #[test]
    fn ddp_when_replica_fits() {
        let hw = ClientHardware::single(A100, 4);
        assert_eq!(
            hw.choose_strategy(20_000_000_000),
            TrainStrategy::Ddp { n_gpus: 4 }
        );
    }

    #[test]
    fn fsdp_when_replica_does_not_fit() {
        let hw = ClientHardware::single(RTX4090, 8);
        // 30 GB > 0.9 * 24 GB.
        assert_eq!(
            hw.choose_strategy(30_000_000_000),
            TrainStrategy::Fsdp { n_gpus: 8 }
        );
    }

    #[test]
    fn subfederation_when_poorly_connected() {
        let hw = ClientHardware {
            nodes: vec![
                NodeSpec { gpu: A40, n_gpus: 2, intra_gbps: 600.0 },
                NodeSpec { gpu: A40, n_gpus: 2, intra_gbps: 600.0 },
            ],
            inter_gbps: 0.1, // WAN
        };
        assert_eq!(
            hw.choose_strategy(1 << 30),
            TrainStrategy::SubFederation { islands: 2 }
        );
    }

    #[test]
    fn footprint_scale() {
        // 7B params → ~140 GB: does not fit one A100, needs FSDP.
        let b = training_footprint_bytes(7_000_000_000);
        assert!(b > 100_000_000_000);
        let hw = ClientHardware::single(A100, 8);
        assert!(matches!(hw.choose_strategy(b), TrainStrategy::Fsdp { .. }));
    }

    #[test]
    fn fleet_constructors() {
        let f = FleetSpec::heterogeneous(6);
        assert_eq!(f.clients.len(), 6);
        assert_eq!(f.clients[0].nodes[0].gpu.name, "A40");
        assert_eq!(f.clients[1].nodes[0].gpu.name, "A100");
        let u = FleetSpec::uniform(3, H100, 2);
        assert!(u.clients.iter().all(|c| c.total_gpus() == 2));
    }
}
