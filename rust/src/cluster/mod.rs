//! Hardware-heterogeneity substrate (paper §5.1/§6.2): GPU catalog,
//! VRAM-driven micro-batch search, connectivity islands with hierarchical
//! sub-federation, and fault (dropout/straggler) injection.
//!
//! The *decision logic* of Algorithm 1 L.14–24 is fully implemented here;
//! the physical math always executes on the single PJRT device (DESIGN.md
//! §1 substitution table).

pub mod batchsize;
pub mod faults;
pub mod hardware;
pub mod island;

pub use batchsize::find_micro_batch;
pub use faults::{FaultPlan, RoundFaults};
pub use hardware::{ClientHardware, FleetSpec, GpuSpec, NodeSpec, TrainStrategy};
pub use island::group_islands;
