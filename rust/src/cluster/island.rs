//! Connectivity islands (paper §5.1 Multi-Machine): group a client's nodes
//! into maximal well-connected components; poorly-connected islands train
//! as a sub-federation whose results are partially aggregated by the lead
//! node before a single update is sent to the Photon Aggregator
//! (Algorithm 1 L.19–24).

use crate::cluster::hardware::{ClientHardware, FleetSpec, INFINIBAND_GBPS};

/// Group node indices into islands. With a single scalar inter-node
/// bandwidth (this fleet model), the result is either one island (well
/// connected) or one island per node (poorly connected); the function takes
/// an explicit pairwise-bandwidth closure so richer topologies (the paper's
/// "islands of nodes with high-bandwidth connections") group correctly too.
pub fn group_islands_by(
    n_nodes: usize,
    bandwidth_gbps: impl Fn(usize, usize) -> f64,
) -> Vec<Vec<usize>> {
    // Union-find over well-connected pairs.
    let mut parent: Vec<usize> = (0..n_nodes).collect();
    fn find(parent: &mut Vec<usize>, x: usize) -> usize {
        if parent[x] != x {
            let r = find(parent, parent[x]);
            parent[x] = r;
        }
        parent[x]
    }
    for i in 0..n_nodes {
        for j in (i + 1)..n_nodes {
            if bandwidth_gbps(i, j) >= INFINIBAND_GBPS {
                let (ri, rj) = (find(&mut parent, i), find(&mut parent, j));
                if ri != rj {
                    parent[ri] = rj;
                }
            }
        }
    }
    let mut groups: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
    for i in 0..n_nodes {
        let r = find(&mut parent, i);
        groups.entry(r).or_default().push(i);
    }
    groups.into_values().collect()
}

/// Islands of a client under its scalar inter-node bandwidth.
pub fn group_islands(hw: &ClientHardware) -> Vec<Vec<usize>> {
    group_islands_by(hw.nodes.len(), |_, _| hw.inter_gbps)
}

/// Island count per client for a (possibly absent) fleet — the stream
/// arity every data-plane participant must agree on. The Aggregator uses
/// it to bind node streams and the deployment plane ships it in the task
/// spec so remote workers bind identically without a fleet config.
pub fn island_counts(fleet: Option<&FleetSpec>, n_clients: usize) -> Vec<usize> {
    (0..n_clients)
        .map(|c| {
            fleet
                .map(|f| group_islands(&f.clients[c]).len())
                .unwrap_or(1)
        })
        .collect()
}

/// Partial aggregation of island results (Algorithm 1 L.23): weighted mean
/// of per-island parameter vectors into a single client update.
pub fn partial_aggregate(island_params: &[Vec<f32>], weights: &[f64]) -> Vec<f32> {
    assert!(!island_params.is_empty());
    assert_eq!(island_params.len(), weights.len());
    let n = island_params[0].len();
    let mut out = vec![0.0f32; n];
    let rows: Vec<&[f32]> = island_params.iter().map(|v| v.as_slice()).collect();
    crate::model::vecmath::weighted_mean_into(&rows, weights, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::hardware::{ClientHardware, NodeSpec, A40};

    fn hw(n_nodes: usize, inter_gbps: f64) -> ClientHardware {
        ClientHardware {
            nodes: vec![NodeSpec { gpu: A40, n_gpus: 2, intra_gbps: 600.0 }; n_nodes],
            inter_gbps,
        }
    }

    #[test]
    fn well_connected_is_one_island() {
        assert_eq!(group_islands(&hw(4, 50.0)), vec![vec![0, 1, 2, 3]]);
    }

    #[test]
    fn wan_nodes_are_singleton_islands() {
        let islands = group_islands(&hw(3, 0.1));
        assert_eq!(islands.len(), 3);
        assert!(islands.iter().all(|i| i.len() == 1));
    }

    #[test]
    fn mixed_topology_groups_pairs() {
        // Nodes 0-1 fast, 2-3 fast, cross slow: two islands of two.
        let bw = |i: usize, j: usize| {
            if (i / 2) == (j / 2) {
                100.0
            } else {
                0.5
            }
        };
        let islands = group_islands_by(4, bw);
        assert_eq!(islands.len(), 2);
        assert!(islands.contains(&vec![0, 1]) && islands.contains(&vec![2, 3]));
    }

    #[test]
    fn partial_aggregate_weighted() {
        let a = vec![0.0f32, 2.0];
        let b = vec![4.0f32, 6.0];
        let out = partial_aggregate(&[a, b], &[3.0, 1.0]);
        assert_eq!(out, vec![1.0, 3.0]);
    }
}
