//! Fault injection: dynamic client availability, stragglers, and dropouts
//! (paper §4: "Every FL system is prone to performance degradation due to
//! dynamic client availability, stragglers, hardware heterogeneity, and
//! unexpected dropouts"). Deterministic per (seed, round, client) so
//! experiments with faults are exactly reproducible.

use crate::util::rng::Rng;

/// Probabilities of per-round client misbehavior.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// P(client drops after being sampled, contributing nothing).
    pub dropout_prob: f64,
    /// P(client straggles: only completes `straggler_fraction·τ` steps).
    pub straggler_prob: f64,
    pub straggler_fraction: f64,
    pub seed: u64,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan { dropout_prob: 0.0, straggler_prob: 0.0, straggler_fraction: 0.5, seed: 0 }
    }

    pub fn new(dropout_prob: f64, straggler_prob: f64, seed: u64) -> FaultPlan {
        FaultPlan { dropout_prob, straggler_prob, straggler_fraction: 0.5, seed }
    }

    pub fn is_none(&self) -> bool {
        self.dropout_prob == 0.0 && self.straggler_prob == 0.0
    }

    /// Faults for one round over the sampled client ids.
    pub fn for_round(&self, round: usize, sampled: &[usize]) -> RoundFaults {
        let mut dropped = Vec::new();
        let mut stragglers = Vec::new();
        if !self.is_none() {
            for &c in sampled {
                let mut rng = Rng::new(
                    self.seed ^ (round as u64).wrapping_mul(0x9E3779B97F4A7C15)
                        ^ (c as u64).wrapping_mul(0xD1B54A32D192ED03),
                );
                if rng.bool(self.dropout_prob) {
                    dropped.push(c);
                } else if rng.bool(self.straggler_prob) {
                    stragglers.push(c);
                }
            }
        }
        RoundFaults { dropped, stragglers, straggler_fraction: self.straggler_fraction }
    }
}

/// The realized faults of one round.
#[derive(Clone, Debug, Default)]
pub struct RoundFaults {
    pub dropped: Vec<usize>,
    pub stragglers: Vec<usize>,
    pub straggler_fraction: f64,
}

impl RoundFaults {
    pub fn is_dropped(&self, client: usize) -> bool {
        self.dropped.contains(&client)
    }

    /// Local steps this client actually completes out of `tau`.
    pub fn effective_steps(&self, client: usize, tau: u64) -> u64 {
        if self.stragglers.contains(&client) {
            ((tau as f64 * self.straggler_fraction).floor() as u64).max(1)
        } else {
            tau
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_is_clean() {
        let f = FaultPlan::none().for_round(3, &[0, 1, 2]);
        assert!(f.dropped.is_empty() && f.stragglers.is_empty());
        assert_eq!(f.effective_steps(1, 100), 100);
    }

    #[test]
    fn deterministic_per_round() {
        let plan = FaultPlan::new(0.3, 0.3, 7);
        let a = plan.for_round(5, &[0, 1, 2, 3, 4, 5, 6, 7]);
        let b = plan.for_round(5, &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.stragglers, b.stragglers);
    }

    #[test]
    fn rates_are_plausible() {
        let plan = FaultPlan::new(0.25, 0.0, 11);
        let mut total_dropped = 0;
        let sampled: Vec<usize> = (0..16).collect();
        for round in 0..200 {
            total_dropped += plan.for_round(round, &sampled).dropped.len();
        }
        let rate = total_dropped as f64 / (200.0 * 16.0);
        assert!((rate - 0.25).abs() < 0.04, "dropout rate {rate}");
    }

    #[test]
    fn dropped_clients_are_not_stragglers() {
        let plan = FaultPlan::new(0.5, 0.9, 3);
        for round in 0..50 {
            let f = plan.for_round(round, &[0, 1, 2, 3]);
            for c in &f.dropped {
                assert!(!f.stragglers.contains(c));
            }
        }
    }

    #[test]
    fn straggler_steps_halved_but_at_least_one() {
        let f = RoundFaults {
            dropped: vec![],
            stragglers: vec![2],
            straggler_fraction: 0.5,
        };
        assert_eq!(f.effective_steps(2, 100), 50);
        assert_eq!(f.effective_steps(2, 1), 1);
        assert_eq!(f.effective_steps(0, 100), 100);
    }
}
