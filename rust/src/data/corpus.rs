//! Synthetic heterogeneous corpora — the C4 / The-Pile / mC4 stand-ins.
//!
//! The paper's heterogeneity experiments partition The Pile by *genre* and
//! mC4 by *language*; what matters for federated optimization is that client
//! data obey measurably different token laws. Each `Category` here is a
//! parametric Markov-Zipf source: the next token is drawn from a Zipf
//! distribution over *ranks* whose mapping to tokens is sheared by the
//! current token (`next = perm[(rank + stride·cur) mod V]`). Different
//! categories get different Zipf exponents, strides, and vocabulary
//! permutations (languages additionally get disjoint vocabulary bands), so
//! per-category unigram AND bigram statistics differ — real statistical
//! heterogeneity with learnable structure (a trained model's perplexity
//! drops well below uniform).

use crate::util::rng::Rng;

/// The Pile genres used in the paper's heterogeneous partition (§6.3).
pub const PILE_GENRES: [&str; 8] = [
    "wikipedia", "arxiv", "gutenberg", "hackernews",
    "pubmed", "freelaw", "philpapers", "stackexchange",
];

/// One synthetic data category (a "genre" or "language").
#[derive(Clone, Debug)]
pub struct Category {
    pub name: String,
    pub vocab: usize,
    /// Zipf exponent for the rank distribution (text-like ≈ 1.0–1.3).
    pub zipf_s: f64,
    /// Bigram shear: how strongly the current token shifts the rank→token map.
    pub stride: usize,
    /// Number of context classes: the shift depends on `cur mod ctx_classes`,
    /// keeping the unigram marginal Zipf-skewed while giving each class its
    /// own bigram law.
    pub ctx_classes: usize,
    /// Fraction of tokens drawn from a *shared* cross-genre process
    /// (real Pile genres share English; languages share nothing).
    pub common_frac: f64,
    /// Token band `[band_lo, band_hi)`; languages use disjoint bands.
    pub band_lo: usize,
    pub band_hi: usize,
    /// Category seed: fixes the vocabulary permutation.
    pub seed: u64,
}

impl Category {
    /// A "genre": full vocabulary, distinct exponent/stride/permutation.
    pub fn genre(name: &str, vocab: usize, index: usize) -> Category {
        Category {
            name: name.to_string(),
            vocab,
            zipf_s: 1.05 + 0.08 * index as f64,
            stride: 3 + 2 * index,
            ctx_classes: 3 + index % 4,
            common_frac: 0.5,
            band_lo: 0,
            band_hi: vocab,
            seed: 0x9e00 + index as u64,
        }
    }

    /// A "language": disjoint vocabulary band (mC4-style, extreme case).
    pub fn language(name: &str, vocab: usize, index: usize, n_langs: usize) -> Category {
        let band = vocab / n_langs;
        Category {
            name: name.to_string(),
            vocab,
            zipf_s: 1.1,
            stride: 5 + index,
            ctx_classes: 4,
            common_frac: 0.0,
            band_lo: index * band,
            band_hi: (index + 1) * band,
            seed: 0x1a00 + index as u64,
        }
    }
}

/// Sampler for one category: precomputed Zipf CDF + vocab permutation.
#[derive(Clone)]
pub struct CategorySampler {
    perm: Vec<u32>,
    cdf: Vec<f64>,
    stride: usize,
    ctx_classes: usize,
    band: usize,
    band_lo: usize,
    common_frac: f64,
    /// Shared cross-genre tables (same for every category of a vocab).
    common_perm: Vec<u32>,
    common_cdf: Vec<f64>,
}

impl CategorySampler {
    pub fn new(cat: &Category) -> CategorySampler {
        let band = cat.band_hi - cat.band_lo;
        assert!(band >= 2, "category band too small");
        // Zipf weights over ranks 1..=band.
        let mut cdf = Vec::with_capacity(band);
        let mut acc = 0.0;
        for r in 1..=band {
            acc += 1.0 / (r as f64).powf(cat.zipf_s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        // Category-specific permutation of the band.
        let mut perm: Vec<u32> = (0..band as u32).collect();
        let mut rng = Rng::new(cat.seed);
        rng.shuffle(&mut perm);
        // Shared "common language" tables: fixed seed + exponent, so every
        // genre of a corpus mixes in the SAME process (paper: Pile genres
        // all share English).
        let mut common_cdf = Vec::with_capacity(band);
        let mut acc_c = 0.0;
        for r in 1..=band {
            acc_c += 1.0 / (r as f64).powf(1.1);
            common_cdf.push(acc_c);
        }
        for c in common_cdf.iter_mut() {
            *c /= acc_c;
        }
        let mut common_perm: Vec<u32> = (0..band as u32).collect();
        let mut crng = Rng::new(0xC0440);
        crng.shuffle(&mut common_perm);
        CategorySampler {
            perm,
            cdf,
            stride: cat.stride,
            ctx_classes: cat.ctx_classes.max(1),
            band,
            band_lo: cat.band_lo,
            common_frac: cat.common_frac,
            common_perm,
            common_cdf,
        }
    }

    /// Draw the next token given the current one. With probability
    /// `common_frac` the token comes from the shared cross-genre process.
    pub fn next_token(&self, cur: u32, rng: &mut Rng) -> u32 {
        let common = self.common_frac > 0.0 && rng.f64() < self.common_frac;
        let (cdf, perm, stride, classes) = if common {
            (&self.common_cdf, &self.common_perm, 7usize, 4usize)
        } else {
            (&self.cdf, &self.perm, self.stride, self.ctx_classes)
        };
        let u = rng.f64();
        // Binary search the CDF for the sampled rank.
        let rank = match cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i,
        }
        .min(self.band - 1);
        let cur_in_band = (cur as usize).saturating_sub(self.band_lo) % self.band;
        let class = cur_in_band % classes;
        let idx = (rank + stride * class) % self.band;
        (self.band_lo + perm[idx] as usize) as u32
    }

    /// Generate a sequence of `len` tokens starting from a sampled token.
    pub fn sequence(&self, len: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut cur = (self.band_lo + rng.usize_below(self.band)) as u32;
        for _ in 0..len {
            cur = self.next_token(cur, rng);
            out.push(cur as i32);
        }
        out
    }
}

/// A named corpus = set of categories (the dataset stand-ins).
#[derive(Clone, Debug)]
pub struct SyntheticCorpus {
    pub name: String,
    pub vocab: usize,
    pub categories: Vec<Category>,
}

impl SyntheticCorpus {
    /// C4 stand-in: one homogeneous mixed source (IID shards, §6.3).
    pub fn c4(vocab: usize) -> SyntheticCorpus {
        SyntheticCorpus {
            name: "c4".into(),
            vocab,
            categories: vec![Category::genre("c4-mix", vocab, 2)],
        }
    }

    /// The-Pile stand-in: the paper's 8 genres (§6.3).
    pub fn pile(vocab: usize) -> SyntheticCorpus {
        SyntheticCorpus {
            name: "pile".into(),
            vocab,
            categories: PILE_GENRES
                .iter()
                .enumerate()
                .map(|(i, g)| Category::genre(g, vocab, i))
                .collect(),
        }
    }

    /// mC4 stand-in: `n` disjoint-vocabulary "languages".
    pub fn mc4(vocab: usize, n_langs: usize) -> SyntheticCorpus {
        let names = ["en", "de", "fr", "zh", "hi", "sw", "ro", "ja"];
        SyntheticCorpus {
            name: "mc4".into(),
            vocab,
            categories: (0..n_langs)
                .map(|i| {
                    Category::language(
                        names.get(i).copied().unwrap_or("xx"),
                        vocab,
                        i,
                        n_langs,
                    )
                })
                .collect(),
        }
    }

    pub fn category(&self, name: &str) -> Option<&Category> {
        self.categories.iter().find(|c| c.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unigram(cat: &Category, n: usize, seed: u64) -> Vec<f64> {
        let s = CategorySampler::new(cat);
        let mut rng = Rng::new(seed);
        let mut counts = vec![0usize; cat.vocab];
        for t in s.sequence(n, &mut rng) {
            counts[t as usize] += 1;
        }
        counts.iter().map(|&c| c as f64 / n as f64).collect()
    }

    #[test]
    fn tokens_in_vocab_range() {
        let corpus = SyntheticCorpus::pile(256);
        for cat in &corpus.categories {
            let s = CategorySampler::new(cat);
            let mut rng = Rng::new(1);
            for t in s.sequence(500, &mut rng) {
                assert!((0..256).contains(&t), "{} out of range", t);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cat = Category::genre("wikipedia", 128, 0);
        let s = CategorySampler::new(&cat);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        assert_eq!(s.sequence(64, &mut r1), s.sequence(64, &mut r2));
    }

    #[test]
    fn genres_have_different_unigram_laws() {
        let corpus = SyntheticCorpus::pile(128);
        let a = unigram(&corpus.categories[0], 20_000, 5);
        let b = unigram(&corpus.categories[4], 20_000, 5);
        // Total-variation distance must be substantial.
        let tv: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f64>() / 2.0;
        assert!(tv > 0.08, "tv distance too small: {tv}");
    }

    #[test]
    fn distribution_is_zipf_skewed() {
        let cat = Category::genre("arxiv", 128, 1);
        let mut u = unigram(&cat, 50_000, 3);
        u.sort_by(|a, b| b.partial_cmp(a).unwrap());
        // Top 10 tokens carry far more than 10/128 of the mass.
        let top10: f64 = u[..10].iter().sum();
        assert!(top10 > 0.3, "top-10 mass {top10}");
    }

    #[test]
    fn languages_use_disjoint_bands() {
        let corpus = SyntheticCorpus::mc4(128, 4);
        for (i, cat) in corpus.categories.iter().enumerate() {
            let s = CategorySampler::new(cat);
            let mut rng = Rng::new(7);
            for t in s.sequence(200, &mut rng) {
                assert!(t as usize >= i * 32 && (t as usize) < (i + 1) * 32);
            }
        }
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // Conditional entropy H(next|cur) must be far below log2(V):
        // the source has predictable structure a model can learn.
        let cat = Category::genre("wikipedia", 64, 0);
        let s = CategorySampler::new(&cat);
        let mut rng = Rng::new(2);
        let mut joint = vec![vec![0usize; 64]; 64];
        let mut cur = 0u32;
        for _ in 0..200_000 {
            let nxt = s.next_token(cur, &mut rng);
            joint[cur as usize][nxt as usize] += 1;
            cur = nxt;
        }
        let mut h_cond = 0.0;
        let total: usize = joint.iter().map(|r| r.iter().sum::<usize>()).sum();
        for row in &joint {
            let rs: usize = row.iter().sum();
            if rs == 0 {
                continue;
            }
            let p_cur = rs as f64 / total as f64;
            let mut h = 0.0;
            for &c in row {
                if c > 0 {
                    let p = c as f64 / rs as f64;
                    h -= p * p.log2();
                }
            }
            h_cond += p_cur * h;
        }
        assert!(h_cond < 5.5, "H(next|cur) = {h_cond} (log2 V = 6)");
        assert!(h_cond > 1.0, "degenerate source: {h_cond}");
    }

    #[test]
    fn corpus_constructors() {
        assert_eq!(SyntheticCorpus::c4(256).categories.len(), 1);
        assert_eq!(SyntheticCorpus::pile(256).categories.len(), 8);
        assert_eq!(SyntheticCorpus::mc4(256, 4).categories.len(), 4);
        assert!(SyntheticCorpus::pile(256).category("arxiv").is_some());
        assert!(SyntheticCorpus::pile(256).category("nope").is_none());
    }
}
