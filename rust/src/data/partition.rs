//! The paper's §6.2.1 partitioner: split each category into `J × |C|`
//! disjoint buckets, map each bucket to at most one client, so "even if two
//! clients draw from the same source, they constantly sample from disjoint
//! data subsets".
//!
//! A `Bucket` is identified by `(category, bucket_idx)`; its stream seed is
//! derived from both, so disjointness is by construction (different seeds =
//! different sample paths) and the invariants (disjointness, ≤1 owner,
//! coverage) are property-tested in rust/tests/props.rs.

use std::collections::BTreeMap;

use crate::data::corpus::SyntheticCorpus;

/// One disjoint shard of a category.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Bucket {
    pub category: String,
    pub index: usize,
}

impl Bucket {
    /// Deterministic stream seed for this bucket (never collides across
    /// (category, index) pairs in practice: FNV over both).
    pub fn seed(&self, experiment_seed: u64) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325 ^ experiment_seed;
        for b in self.category.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= self.index as u64;
        h.wrapping_mul(0x100000001b3)
    }
}

/// A client→buckets assignment over a corpus.
#[derive(Clone, Debug)]
pub struct Partition {
    pub corpus_name: String,
    pub n_clients: usize,
    /// Max categories a client may draw on (J in the paper).
    pub j: usize,
    /// client id → owned buckets.
    pub assignment: Vec<Vec<Bucket>>,
    /// Buckets reserved for validation (never assigned to clients).
    pub validation: Vec<Bucket>,
}

impl Partition {
    /// IID partition (the paper's homogeneous C4 setting): every client gets
    /// one bucket of the single mixed category; bucket |C| is held out for
    /// validation.
    pub fn iid(corpus: &SyntheticCorpus, n_clients: usize) -> Partition {
        assert_eq!(
            corpus.categories.len(),
            1,
            "iid partition expects a single-category corpus"
        );
        let cat = &corpus.categories[0].name;
        let assignment = (0..n_clients)
            .map(|c| vec![Bucket { category: cat.clone(), index: c }])
            .collect();
        Partition {
            corpus_name: corpus.name.clone(),
            n_clients,
            j: 1,
            assignment,
            validation: vec![Bucket { category: cat.clone(), index: n_clients }],
        }
    }

    /// Natural heterogeneous partition (the paper's Pile setting): client
    /// `c` draws on `j` categories, chosen round-robin, each contributing a
    /// private bucket. With `j = 1` and `n_clients == |categories|`, this is
    /// the paper's one-genre-per-client mapping.
    pub fn heterogeneous(corpus: &SyntheticCorpus, n_clients: usize, j: usize) -> Partition {
        assert!(!corpus.categories.is_empty());
        assert!(j >= 1);
        let n_cat = corpus.categories.len();
        let mut next_bucket: BTreeMap<String, usize> = BTreeMap::new();
        let mut assignment = Vec::with_capacity(n_clients);
        for c in 0..n_clients {
            let mut mine = Vec::with_capacity(j);
            for k in 0..j {
                let cat = &corpus.categories[(c + k) % n_cat].name;
                let idx = next_bucket.entry(cat.clone()).or_insert(0);
                mine.push(Bucket { category: cat.clone(), index: *idx });
                *idx += 1;
            }
            assignment.push(mine);
        }
        // One held-out validation bucket per category, indices above any
        // assigned bucket.
        let validation = corpus
            .categories
            .iter()
            .map(|cat| Bucket {
                category: cat.name.clone(),
                index: next_bucket.get(&cat.name).copied().unwrap_or(0),
            })
            .collect();
        Partition {
            corpus_name: corpus.name.clone(),
            n_clients,
            j,
            assignment,
            validation,
        }
    }

    /// Buckets-per-category upper bound from the paper: `J × |C|`.
    pub fn max_buckets_per_category(&self) -> usize {
        self.j * self.n_clients
    }

    /// All assigned buckets (flattened).
    pub fn all_buckets(&self) -> Vec<&Bucket> {
        self.assignment.iter().flatten().collect()
    }

    /// Owner of a bucket, if any.
    pub fn owner(&self, b: &Bucket) -> Option<usize> {
        self.assignment
            .iter()
            .position(|bs| bs.iter().any(|x| x == b))
    }

    /// Invariant check used by tests and at federation startup:
    /// no bucket owned twice, validation buckets unassigned, indices within
    /// the J×|C| bound.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = std::collections::BTreeSet::new();
        for (c, bs) in self.assignment.iter().enumerate() {
            for b in bs {
                if !seen.insert(b.clone()) {
                    return Err(format!("bucket {b:?} assigned twice (client {c})"));
                }
                if b.index >= self.max_buckets_per_category() + 1 {
                    return Err(format!("bucket {b:?} beyond J*|C| bound"));
                }
            }
        }
        for v in &self.validation {
            if seen.contains(v) {
                return Err(format!("validation bucket {v:?} also assigned"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticCorpus;

    #[test]
    fn iid_buckets_disjoint() {
        let p = Partition::iid(&SyntheticCorpus::c4(128), 8);
        p.check_invariants().unwrap();
        assert_eq!(p.assignment.len(), 8);
        assert_eq!(p.validation.len(), 1);
        assert_eq!(p.owner(&p.assignment[3][0]), Some(3));
        assert_eq!(p.owner(&p.validation[0]), None);
    }

    #[test]
    fn hetero_one_genre_per_client() {
        let corpus = SyntheticCorpus::pile(128);
        let p = Partition::heterogeneous(&corpus, 8, 1);
        p.check_invariants().unwrap();
        // With 8 clients, 8 genres, J=1: each client gets exactly its genre.
        for (c, bs) in p.assignment.iter().enumerate() {
            assert_eq!(bs.len(), 1);
            assert_eq!(bs[0].category, corpus.categories[c].name);
        }
    }

    #[test]
    fn hetero_multi_category_clients() {
        let corpus = SyntheticCorpus::pile(128);
        let p = Partition::heterogeneous(&corpus, 12, 3);
        p.check_invariants().unwrap();
        for bs in &p.assignment {
            assert_eq!(bs.len(), 3);
            // Client's categories are distinct.
            let mut cats: Vec<_> = bs.iter().map(|b| &b.category).collect();
            cats.sort();
            cats.dedup();
            assert_eq!(cats.len(), 3);
        }
    }

    #[test]
    fn more_clients_than_categories_share_categories_not_buckets() {
        let corpus = SyntheticCorpus::pile(128);
        let p = Partition::heterogeneous(&corpus, 64, 1);
        p.check_invariants().unwrap();
        // Clients 0 and 8 share the genre but not the bucket.
        assert_eq!(p.assignment[0][0].category, p.assignment[8][0].category);
        assert_ne!(p.assignment[0][0], p.assignment[8][0]);
    }

    #[test]
    fn bucket_seeds_unique() {
        let corpus = SyntheticCorpus::pile(128);
        let p = Partition::heterogeneous(&corpus, 64, 2);
        let mut seeds: Vec<u64> =
            p.all_buckets().iter().map(|b| b.seed(42)).collect();
        seeds.sort_unstable();
        let before = seeds.len();
        seeds.dedup();
        assert_eq!(seeds.len(), before, "seed collision");
    }

    #[test]
    fn seed_depends_on_experiment_seed() {
        let b = Bucket { category: "arxiv".into(), index: 3 };
        assert_ne!(b.seed(1), b.seed(2));
    }
}
