//! Photon Data Source substrate: synthetic heterogeneous corpora, the
//! J×|C| bucket partitioner (paper §6.2.1), and checkpointable token
//! streams feeding the Photon LLM Nodes (paper §5.2).

pub mod corpus;
pub mod partition;
pub mod source;
pub mod stream;

pub use corpus::{Category, SyntheticCorpus};
pub use partition::{Bucket, Partition};
pub use source::DataSource;
pub use stream::TokenStream;
