//! Photon Data Source substrate: synthetic heterogeneous corpora, the
//! J×|C| bucket partitioner (paper §6.2.1), and checkpointable token
//! streams feeding the Photon LLM Nodes (paper §5.2).
//!
//! Pipeline: a [`SyntheticCorpus`] defines per-[`Category`] token
//! statistics (C4-like homogeneous, Pile-like heterogeneous, or
//! disjoint-vocabulary mC4); a [`Partition`] assigns `j` category
//! buckets to each of the P clients (IID shards or natural
//! heterogeneity); [`DataSource`] binds the two under the experiment
//! seed; and each client node pulls batches from seeded
//! [`TokenStream`]s whose cursors serialize into checkpoints — resume
//! is sample-exact, one cursor per connectivity island.

pub mod corpus;
pub mod partition;
pub mod source;
pub mod stream;

pub use corpus::{Category, SyntheticCorpus};
pub use partition::{Bucket, Partition};
pub use source::DataSource;
pub use stream::TokenStream;
