//! Checkpointable token streams (the MosaicML StreamingDataset analogue,
//! paper §5.2): a client's stream mixes its assigned buckets and yields
//! `[batch, seq_len+1]` training batches; its cursor state serializes into
//! checkpoints so training resumes sample-exact (paper §4.1: "the local
//! state must track ... data loading index states").

use anyhow::{bail, ensure, Result};

use crate::data::corpus::{Category, CategorySampler};
use crate::data::partition::Bucket;
use crate::util::rng::Rng;

/// Stream over one bucket: an endless sampler with its own RNG.
#[derive(Clone)]
struct BucketStream {
    sampler: CategorySampler,
    rng: Rng,
    /// Sequences drawn so far (monitoring + checkpoint metadata).
    drawn: u64,
}

/// A client's merged data stream (Algorithm 1 L.13 `BindStream`).
#[derive(Clone)]
pub struct TokenStream {
    buckets: Vec<BucketStream>,
    bucket_ids: Vec<Bucket>,
    /// Mixing RNG choosing which bucket serves the next sequence.
    mix_rng: Rng,
    pub seq_width: usize,
}

/// Serializable cursor state (see ckpt module).
#[derive(Clone, Debug, PartialEq)]
pub struct StreamCursor {
    pub mix_state: [u64; 4],
    pub bucket_states: Vec<([u64; 4], u64)>,
}

impl TokenStream {
    /// Bind buckets into one stream. `categories` must contain the category
    /// of every bucket — a bucket naming a category the corpus does not
    /// carry is a configuration error (bad partition vs corpus pairing) and
    /// fails the bind instead of panicking the process, so a federation
    /// round can report it and keep the Aggregator alive.
    /// `seq_width = seq_len + 1` (inputs + shifted targets).
    pub fn bind(
        buckets: &[Bucket],
        categories: &[Category],
        seq_width: usize,
        experiment_seed: u64,
    ) -> Result<TokenStream> {
        ensure!(!buckets.is_empty(), "stream needs at least one bucket");
        let streams = buckets
            .iter()
            .map(|b| {
                let Some(cat) = categories.iter().find(|c| c.name == b.category) else {
                    bail!(
                        "bucket references unknown category {:?} (corpus carries: {}) \
                         — partition and corpus configs disagree",
                        b.category,
                        categories
                            .iter()
                            .map(|c| c.name.as_str())
                            .collect::<Vec<_>>()
                            .join(", ")
                    );
                };
                Ok(BucketStream {
                    sampler: CategorySampler::new(cat),
                    rng: Rng::new(b.seed(experiment_seed)),
                    drawn: 0,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mix_seed = buckets
            .iter()
            .fold(experiment_seed ^ 0x51_7e_a1, |acc, b| {
                acc.wrapping_mul(31).wrapping_add(b.seed(experiment_seed))
            });
        Ok(TokenStream {
            buckets: streams,
            bucket_ids: buckets.to_vec(),
            mix_rng: Rng::new(mix_seed),
            seq_width,
        })
    }

    /// One training sequence of `seq_width` tokens.
    pub fn next_sequence(&mut self) -> Vec<i32> {
        let k = self.mix_rng.usize_below(self.buckets.len());
        let b = &mut self.buckets[k];
        b.drawn += 1;
        b.sampler.sequence(self.seq_width, &mut b.rng)
    }

    /// One `[batch, seq_width]` row-major batch.
    pub fn next_batch(&mut self, batch: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * self.seq_width);
        for _ in 0..batch {
            out.extend(self.next_sequence());
        }
        out
    }

    /// Total sequences drawn (quantity-skew accounting / FedAvg weighting).
    pub fn sequences_drawn(&self) -> u64 {
        self.buckets.iter().map(|b| b.drawn).sum()
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.bucket_ids
    }

    pub fn cursor(&self) -> StreamCursor {
        StreamCursor {
            mix_state: self.mix_rng.state(),
            bucket_states: self
                .buckets
                .iter()
                .map(|b| (b.rng.state(), b.drawn))
                .collect(),
        }
    }

    /// Restore a cursor (bucket arity must match).
    pub fn restore(&mut self, cur: &StreamCursor) {
        assert_eq!(cur.bucket_states.len(), self.buckets.len());
        self.mix_rng = Rng::from_state(cur.mix_state);
        for (b, (st, drawn)) in self.buckets.iter_mut().zip(&cur.bucket_states) {
            b.rng = Rng::from_state(*st);
            b.drawn = *drawn;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::SyntheticCorpus;
    use crate::data::partition::Partition;

    fn toy_stream(seed: u64) -> TokenStream {
        let corpus = SyntheticCorpus::pile(64);
        let p = Partition::heterogeneous(&corpus, 4, 2);
        TokenStream::bind(&p.assignment[0], &corpus.categories, 9, seed).unwrap()
    }

    #[test]
    fn unknown_category_is_an_error_not_a_panic() {
        let corpus = SyntheticCorpus::pile(64);
        let bogus = [crate::data::partition::Bucket {
            category: "not_a_real_genre".into(),
            index: 0,
        }];
        let err = TokenStream::bind(&bogus, &corpus.categories, 9, 1)
            .err()
            .expect("bad partition config must fail the bind")
            .to_string();
        assert!(err.contains("not_a_real_genre"), "{err}");
        assert!(err.contains("corpus carries"), "{err}");
    }

    #[test]
    fn batch_shape_and_range() {
        let mut s = toy_stream(1);
        let b = s.next_batch(4);
        assert_eq!(b.len(), 4 * 9);
        assert!(b.iter().all(|&t| (0..64).contains(&t)));
        assert_eq!(s.sequences_drawn(), 4);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = toy_stream(7);
        let mut b = toy_stream(7);
        for _ in 0..5 {
            assert_eq!(a.next_batch(2), b.next_batch(2));
        }
    }

    #[test]
    fn different_seeds_different_data() {
        let mut a = toy_stream(1);
        let mut b = toy_stream(2);
        assert_ne!(a.next_batch(2), b.next_batch(2));
    }

    #[test]
    fn disjoint_buckets_give_disjoint_sample_paths() {
        let corpus = SyntheticCorpus::c4(64);
        let p = Partition::iid(&corpus, 2);
        let mut s0 =
            TokenStream::bind(&p.assignment[0], &corpus.categories, 9, 3).unwrap();
        let mut s1 =
            TokenStream::bind(&p.assignment[1], &corpus.categories, 9, 3).unwrap();
        assert_ne!(s0.next_batch(4), s1.next_batch(4));
    }

    #[test]
    fn cursor_roundtrip_resumes_exactly() {
        let mut s = toy_stream(11);
        s.next_batch(3);
        let cur = s.cursor();
        let ahead = s.next_batch(2);
        // Rewind and replay.
        s.restore(&cur);
        assert_eq!(s.next_batch(2), ahead);
        assert_eq!(s.cursor().bucket_states.len(), 2);
    }
}
