//! Photon Data Source: the storage-side component bound to one Photon LLM
//! Node (paper §4.1). It owns the client's training stream and serves the
//! held-out validation split ("Photon Data Source ensures this split is
//! preserved and streamed to the Photon LLM Nodes when asked to validate").

use anyhow::Result;

use crate::data::corpus::SyntheticCorpus;
use crate::data::partition::Partition;
use crate::data::stream::TokenStream;

/// A federation's data plane: per-client sources + a shared validation set.
pub struct DataSource {
    pub corpus: SyntheticCorpus,
    pub partition: Partition,
    pub experiment_seed: u64,
}

impl DataSource {
    pub fn new(corpus: SyntheticCorpus, partition: Partition, experiment_seed: u64) -> Self {
        partition
            .check_invariants()
            .expect("partition invariants violated");
        DataSource { corpus, partition, experiment_seed }
    }

    /// Bind client `c`'s buckets to a merged training stream
    /// (Algorithm 1 L.13).
    pub fn bind_stream(&self, client: usize, seq_width: usize) -> Result<TokenStream> {
        TokenStream::bind(
            &self.partition.assignment[client],
            &self.corpus.categories,
            seq_width,
            self.experiment_seed,
        )
    }

    /// The centralized validation set: a fixed list of `[batch, seq_width]`
    /// batches drawn from the held-out validation buckets. Deterministic per
    /// experiment seed, identical for every caller — the "centralized
    /// validation set" the paper's figures evaluate server models on.
    pub fn validation_batches(
        &self,
        n_batches: usize,
        batch: usize,
        seq_width: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let mut stream = TokenStream::bind(
            &self.partition.validation,
            &self.corpus.categories,
            seq_width,
            self.experiment_seed ^ 0x7a11_da7e,
        )?;
        Ok((0..n_batches).map(|_| stream.next_batch(batch)).collect())
    }

    /// A client's *personal* validation stream (paper §4.2: personalized
    /// evaluation on one client's private test set) — same buckets as
    /// training but an independent sample path.
    pub fn client_validation_batches(
        &self,
        client: usize,
        n_batches: usize,
        batch: usize,
        seq_width: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let mut stream = TokenStream::bind(
            &self.partition.assignment[client],
            &self.corpus.categories,
            seq_width,
            self.experiment_seed ^ 0x9c11e47,
        )?;
        Ok((0..n_batches).map(|_| stream.next_batch(batch)).collect())
    }

    pub fn n_clients(&self) -> usize {
        self.partition.n_clients
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::Partition;

    fn source() -> DataSource {
        let corpus = SyntheticCorpus::pile(64);
        let partition = Partition::heterogeneous(&corpus, 8, 1);
        DataSource::new(corpus, partition, 5)
    }

    #[test]
    fn validation_is_deterministic_and_shared() {
        let s = source();
        let a = s.validation_batches(3, 2, 9).unwrap();
        let b = s.validation_batches(3, 2, 9).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].len(), 2 * 9);
    }

    #[test]
    fn validation_differs_from_training() {
        let s = source();
        let val = s.validation_batches(1, 2, 9).unwrap();
        let mut train = s.bind_stream(0, 9).unwrap();
        assert_ne!(val[0], train.next_batch(2));
    }

    #[test]
    fn client_validation_is_personal() {
        let s = source();
        // Clients hold different genres => different personal val sets.
        let v0 = s.client_validation_batches(0, 1, 2, 9).unwrap();
        let v1 = s.client_validation_batches(1, 1, 2, 9).unwrap();
        assert_ne!(v0, v1);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_partition() {
        let corpus = SyntheticCorpus::pile(64);
        let mut partition = Partition::heterogeneous(&corpus, 4, 1);
        // Sabotage: duplicate a bucket.
        let b = partition.assignment[0][0].clone();
        partition.assignment[1][0] = b;
        DataSource::new(corpus, partition, 1);
    }
}
