"""L1 correctness: Pallas flash-attention kernel vs the pure-jnp oracle.

This is the CORE numerical signal for the kernel layer. Hypothesis sweeps
shapes, dtypes, and block sizes; dedicated tests pin causality, ALiBi, the
online-softmax stability, and the custom-VJP (training) wrapper.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_attention import (
    flash_attention,
    flash_attention_trainable,
    vmem_footprint_bytes,
)
from compile.kernels.ref import alibi_bias, alibi_slopes, attention_ref

jax.config.update("jax_platform_name", "cpu")


def _rand_qkv(rng, b, h, l, d, dtype=np.float32, scale=1.0):
    mk = lambda: jnp.asarray(
        rng.standard_normal((b, h, l, d)) * scale, dtype)
    return mk(), mk(), mk()


def _divisors(n):
    return [d for d in range(1, n + 1) if n % d == 0]


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes / dtypes / block sizes
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    l=st.sampled_from([8, 16, 32, 64]),
    d=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_shapes(b, h, l, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _rand_qkv(rng, b, h, l, d)
    slopes = alibi_slopes(h)
    ref = attention_ref(q, k, v, slopes)
    out = flash_attention(q, k, v, slopes)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    l=st.sampled_from([16, 32, 64]),
    bq_i=st.integers(0, 10),
    bk_i=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_block_size_invariance(l, bq_i, bk_i, seed):
    """Any (block_q, block_k) tiling of L gives the same numbers."""
    divs = [dv for dv in _divisors(l) if dv >= 2]
    bq = divs[bq_i % len(divs)]
    bk = divs[bk_i % len(divs)]
    rng = np.random.default_rng(seed)
    q, k, v = _rand_qkv(rng, 2, 2, l, 8)
    slopes = alibi_slopes(2)
    ref = attention_ref(q, k, v, slopes)
    out = flash_attention(q, k, v, slopes, block_q=bq, block_k=bk)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    dtype=st.sampled_from([np.float32, np.float16]),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_dtypes_and_scales(dtype, scale, seed):
    """f16 inputs and large-magnitude scores: online softmax must stay stable."""
    rng = np.random.default_rng(seed)
    q, k, v = _rand_qkv(rng, 1, 2, 32, 8, dtype=dtype, scale=scale)
    slopes = alibi_slopes(2)
    ref = attention_ref(q, k, v, slopes)
    out = flash_attention(q, k, v, slopes, block_q=8, block_k=8)
    assert np.isfinite(np.asarray(out)).all()
    # Tolerance scales with score magnitude: at scale≈10 the logits are
    # O(100) and the online-softmax accumulation order differs from the
    # fused reference by a few f32 ulps of exp(large).
    base = 2e-5 if dtype == np.float32 else 2e-3
    tol = base * max(1.0, scale * 2.0)
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# Semantics pins
# ---------------------------------------------------------------------------

def test_causality_future_tokens_do_not_leak():
    rng = np.random.default_rng(7)
    q, k, v = _rand_qkv(rng, 1, 2, 32, 8)
    out1 = flash_attention(q, k, v, alibi_slopes(2), block_q=8, block_k=8)
    # Perturb the *last* key/value; all but the final query row must be equal.
    k2 = k.at[:, :, -1, :].set(99.0)
    v2 = v.at[:, :, -1, :].set(-99.0)
    out2 = flash_attention(q, k2, v2, alibi_slopes(2), block_q=8, block_k=8)
    np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1],
                               rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[:, :, -1], out2[:, :, -1])


def test_first_position_is_value_passthrough():
    """Row 0 attends only to itself => out[...,0,:] == v[...,0,:]."""
    rng = np.random.default_rng(3)
    q, k, v = _rand_qkv(rng, 2, 2, 16, 8)
    out = flash_attention(q, k, v, alibi_slopes(2), block_q=8, block_k=8)
    np.testing.assert_allclose(out[:, :, 0], v[:, :, 0], rtol=1e-6, atol=1e-6)


def test_alibi_slopes_power_of_two():
    s = alibi_slopes(8)
    np.testing.assert_allclose(s, [2 ** (-i) for i in range(1, 9)], rtol=1e-6)


def test_alibi_slopes_non_power_of_two():
    s = alibi_slopes(12)
    assert len(s) == 12
    assert (s > 0).all() and (s <= 1.0).all()
    # First 8 entries are the 8-head slopes.
    np.testing.assert_allclose(s[:8], alibi_slopes(8), rtol=1e-6)


def test_alibi_bias_structure():
    b = np.asarray(alibi_bias(jnp.asarray(alibi_slopes(2)), 6))
    assert b.shape == (2, 6, 6)
    # Zero on the diagonal, -slope * distance below it.
    np.testing.assert_allclose(np.diagonal(b, axis1=1, axis2=2), 0.0)
    np.testing.assert_allclose(b[0, 3, 1], -alibi_slopes(2)[0] * 2, rtol=1e-6)


def test_alibi_actually_changes_output():
    rng = np.random.default_rng(11)
    q, k, v = _rand_qkv(rng, 1, 1, 32, 8)
    out_alibi = flash_attention(q, k, v, np.asarray([0.5], np.float32))
    out_plain = flash_attention(q, k, v, np.asarray([0.0], np.float32))
    assert not np.allclose(out_alibi, out_plain)


def test_indivisible_block_raises():
    rng = np.random.default_rng(0)
    q, k, v = _rand_qkv(rng, 1, 1, 12, 4)
    with pytest.raises(ValueError):
        flash_attention(q, k, v, alibi_slopes(1), block_q=8, block_k=8)


# ---------------------------------------------------------------------------
# Training wrapper (custom VJP)
# ---------------------------------------------------------------------------

def test_trainable_forward_matches_kernel():
    rng = np.random.default_rng(5)
    q, k, v = _rand_qkv(rng, 2, 2, 32, 8)
    s = alibi_slopes(2)
    np.testing.assert_allclose(
        flash_attention_trainable(q, k, v, s, 16, 16),
        flash_attention(q, k, v, s, block_q=16, block_k=16),
        rtol=1e-6, atol=1e-6)


def test_trainable_gradients_match_ref_gradients():
    rng = np.random.default_rng(9)
    q, k, v = _rand_qkv(rng, 1, 2, 16, 8)
    s = alibi_slopes(2)

    def loss_kernel(q, k, v):
        return jnp.sum(flash_attention_trainable(q, k, v, s, 8, 8) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, s) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_vmem_footprint_within_budget():
    """The DESIGN.md TPU blocking (128/128, d<=256) must fit ~16MB VMEM."""
    assert vmem_footprint_bytes(128, 128, 2048, 256) < 16 * 2 ** 20
