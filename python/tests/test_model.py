"""L2 correctness: transformer forward, flat packing, AdamW step, eval/score."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, model
from compile.configs import ModelConfig

jax.config.update("jax_platform_name", "cpu")

CFG = configs.BY_NAME["m75a"]
PALLAS_CFG = configs.BY_NAME["tiny_pallas"]


def _tokens(cfg, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    b = batch or cfg.batch_size
    return jnp.asarray(
        rng.integers(0, cfg.vocab, (b, cfg.seq_len + 1)), jnp.int32)


def _flat(cfg, seed=0):
    return jnp.asarray(model.init_params_np(cfg, seed))


# ---------------------------------------------------------------------------
# Layout / packing
# ---------------------------------------------------------------------------

def test_layout_matches_param_count_formula():
    for cfg in configs.CONFIGS:
        assert model.n_params(cfg) == configs.param_count(cfg), cfg.name


def test_layout_offsets_are_contiguous():
    ents, total = model.layout_with_offsets(CFG)
    off = 0
    for name, shape, o, size, _ in ents:
        assert o == off, name
        assert size == int(np.prod(shape))
        off += size
    assert off == total


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.default_rng(seed)
    flat = jnp.asarray(rng.standard_normal(model.n_params(CFG)), jnp.float32)
    again = model.pack(model.unpack(flat, CFG), CFG)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(again))


def test_decay_mask_excludes_layernorm_gains():
    mask = model.decay_mask(CFG)
    ents, total = model.layout_with_offsets(CFG)
    assert mask.shape == (total,)
    for name, shape, off, size, _ in ents:
        expected = 1.0 if len(shape) > 1 else 0.0
        assert (mask[off: off + size] == expected).all(), name


def test_init_stats():
    flat = model.init_params_np(CFG, seed=3)
    ents, _ = model.layout_with_offsets(CFG)
    for name, shape, off, size, init in ents:
        seg = flat[off: off + size]
        if init["kind"] == "ones":
            assert (seg == 1.0).all(), name
        else:
            assert abs(seg.mean()) < 5 * init["std"] / np.sqrt(size), name
            assert abs(seg.std() - init["std"]) < 0.25 * init["std"], name


# ---------------------------------------------------------------------------
# Forward semantics
# ---------------------------------------------------------------------------

def test_forward_shapes_all_configs():
    for cfg in configs.CONFIGS:
        if cfg.name == "e2e":  # skip the big one for speed
            continue
        flat = _flat(cfg)
        toks = _tokens(cfg)[:, :-1]
        logits, act = model.forward(flat, toks, cfg)
        assert logits.shape == (cfg.batch_size, cfg.seq_len, cfg.vocab)
        assert np.isfinite(float(act))


def test_forward_is_causal():
    """Changing token t only affects logits at positions >= t."""
    flat = _flat(CFG)
    toks = _tokens(CFG)[:, :-1]
    logits1, _ = model.forward(flat, toks, CFG)
    t = CFG.seq_len // 2
    toks2 = toks.at[:, t].set((toks[:, t] + 1) % CFG.vocab)
    logits2, _ = model.forward(flat, toks2, CFG)
    np.testing.assert_allclose(logits1[:, :t], logits2[:, :t],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(logits1[:, t:], logits2[:, t:])


def test_pallas_model_matches_jnp_model():
    """Full-model forward with the L1 kernel == with the jnp oracle."""
    flat = _flat(CFG)
    toks = _tokens(CFG)[:, :-1]
    logits_jnp, act_jnp = model.forward(flat, toks, CFG)
    logits_pal, act_pal = model.forward(flat, toks, PALLAS_CFG)
    np.testing.assert_allclose(logits_pal, logits_jnp, rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(act_pal, act_jnp, rtol=5e-5, atol=5e-5)


def test_initial_loss_near_uniform():
    """Fresh init => loss ~ ln(vocab), the classic sanity pin."""
    flat = _flat(CFG)
    toks = _tokens(CFG)
    loss, _ = model.loss_fn(flat, toks[:, :-1], toks[:, 1:], CFG)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def _run_steps(cfg, n, lr=3e-3, seed=0):
    fns = model.step_fns(cfg)
    ts = jax.jit(fns["train_step"])
    flat = _flat(cfg, seed)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    toks = _tokens(cfg, seed)
    losses = []
    for i in range(1, n + 1):
        flat, m, v, loss, gn, un, an = ts(
            flat, m, v, jnp.asarray(i, jnp.int32),
            jnp.asarray(lr, jnp.float32), toks)
        losses.append(float(loss))
    return flat, losses


def test_train_step_decreases_loss():
    _, losses = _run_steps(CFG, 25)
    assert losses[-1] < losses[0] - 1.0, losses


def test_train_step_pallas_matches_jnp():
    """The pallas-lowered train step follows the same trajectory."""
    f_jnp, l_jnp = _run_steps(CFG, 5)
    f_pal, l_pal = _run_steps(PALLAS_CFG, 5)
    np.testing.assert_allclose(l_pal, l_jnp, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(f_pal), np.asarray(f_jnp),
                               rtol=1e-3, atol=1e-4)


def test_train_step_metrics_finite_and_positive():
    fns = model.step_fns(CFG)
    ts = jax.jit(fns["train_step"])
    flat = _flat(CFG)
    z = jnp.zeros_like(flat)
    out = ts(flat, z, z, jnp.asarray(1, jnp.int32),
             jnp.asarray(1e-3, jnp.float32), _tokens(CFG))
    _, _, _, loss, gn, un, an = out
    for x in (loss, gn, un, an):
        assert np.isfinite(float(x)) and float(x) > 0


def test_adamw_matches_reference_implementation():
    """One fused step == a hand-written numpy AdamW on the same gradient."""
    cfg = CFG
    flat = _flat(cfg, 1)
    toks = _tokens(cfg, 1)
    lr = 1e-3

    grads = jax.grad(
        lambda f: model.loss_fn(f, toks[:, :-1], toks[:, 1:], cfg)[0])(flat)
    g = np.asarray(grads, np.float64)
    gn = np.linalg.norm(g)
    g = g * min(1.0, cfg.clip_norm / (gn + 1e-6))
    m = (1 - cfg.beta1) * g
    v = (1 - cfg.beta2) * g * g
    m_hat = m / (1 - cfg.beta1)
    v_hat = v / (1 - cfg.beta2)
    mask = model.decay_mask(cfg)
    expected = (np.asarray(flat, np.float64)
                - lr * (m_hat / (np.sqrt(v_hat) + cfg.eps)
                        + cfg.weight_decay * mask * np.asarray(flat)))

    fns = model.step_fns(cfg)
    out = jax.jit(fns["train_step"])(
        flat, jnp.zeros_like(flat), jnp.zeros_like(flat),
        jnp.asarray(1, jnp.int32), jnp.asarray(lr, jnp.float32), toks)
    np.testing.assert_allclose(np.asarray(out[0]), expected,
                               rtol=2e-4, atol=2e-6)


def test_lr_zero_is_identity():
    fns = model.step_fns(CFG)
    flat = _flat(CFG)
    z = jnp.zeros_like(flat)
    out = jax.jit(fns["train_step"])(
        flat, z, z, jnp.asarray(1, jnp.int32),
        jnp.asarray(0.0, jnp.float32), _tokens(CFG))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(flat))


# ---------------------------------------------------------------------------
# Eval / score steps
# ---------------------------------------------------------------------------

def test_eval_step_consistent_with_loss():
    fns = model.step_fns(CFG)
    flat = _flat(CFG)
    toks = _tokens(CFG)
    s, n = jax.jit(fns["eval_step"])(flat, toks)
    loss, _ = model.loss_fn(flat, toks[:, :-1], toks[:, 1:], CFG)
    assert float(n) == CFG.batch_size * CFG.seq_len
    np.testing.assert_allclose(float(s) / float(n), float(loss), rtol=1e-5)


def test_score_step_mask_selects_positions():
    fns = model.step_fns(CFG)
    flat = _flat(CFG)
    toks = _tokens(CFG)
    full_mask = jnp.ones((CFG.batch_size, CFG.seq_len), jnp.float32)
    ll_full, len_full = jax.jit(fns["score_step"])(flat, toks, full_mask)
    assert (np.asarray(len_full) == CFG.seq_len).all()
    # Mask = 0 => zero log-likelihood contribution.
    zero_mask = jnp.zeros_like(full_mask)
    ll_zero, len_zero = jax.jit(fns["score_step"])(flat, toks, zero_mask)
    np.testing.assert_allclose(np.asarray(ll_zero), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(len_zero), 0.0)
    # Half mask sums a subset: |ll_half| <= |sum of per-token lls| of full.
    half = full_mask.at[:, : CFG.seq_len // 2].set(0.0)
    ll_half, len_half = jax.jit(fns["score_step"])(flat, toks, half)
    assert (np.asarray(len_half) == CFG.seq_len // 2).all()
    assert (np.abs(np.asarray(ll_half)) <= np.abs(np.asarray(ll_full)) + 1e-4).all()


def test_example_args_signatures():
    for which in ("train_step", "eval_step", "score_step"):
        args = model.example_args(CFG, which)
        assert all(hasattr(a, "shape") for a in args)
    with pytest.raises(ValueError):
        model.example_args(CFG, "nope")


# ---------------------------------------------------------------------------
# Chunked train step (perf pass)
# ---------------------------------------------------------------------------

def test_train_chunk_matches_single_steps():
    """train_chunk == TRAIN_CHUNK consecutive train_steps, same trajectory."""
    cfg = CFG
    fns = model.step_fns(cfg)
    ts = jax.jit(fns["train_step"])
    tc = jax.jit(fns["train_chunk"])
    k = model.TRAIN_CHUNK

    rng = np.random.default_rng(5)
    toks_np = rng.integers(0, cfg.vocab, (k, cfg.batch_size, cfg.seq_len + 1))
    toks = jnp.asarray(toks_np, jnp.int32)
    lrs = jnp.asarray(3e-3 * (1.0 + 0.1 * np.arange(k)), jnp.float32)

    # Reference: k single steps.
    flat = _flat(cfg, 5)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    ref_losses = []
    f_r, m_r, v_r = flat, m, v
    for i in range(k):
        f_r, m_r, v_r, loss, gn, un, an = ts(
            f_r, m_r, v_r, jnp.asarray(i + 1, jnp.int32), lrs[i], toks[i])
        ref_losses.append(float(loss))

    # Chunked: one dispatch.
    f_c, m_c, v_c, losses, gns, uns, ans = tc(
        flat, m, v, jnp.asarray(0, jnp.int32), lrs, toks)
    np.testing.assert_allclose(np.asarray(losses), ref_losses, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(f_c), np.asarray(f_r),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(m_c), np.asarray(m_r),
                               rtol=1e-5, atol=1e-8)
    assert np.asarray(gns).shape == (k,)
    assert np.isfinite(np.asarray(ans)).all()


def test_train_chunk_respects_step_offset():
    """Bias correction must continue from step0 (mid-training chunk)."""
    cfg = CFG
    fns = model.step_fns(cfg)
    tc = jax.jit(fns["train_chunk"])
    k = model.TRAIN_CHUNK
    flat = _flat(cfg, 6)
    m = jnp.ones_like(flat) * 1e-4
    v = jnp.ones_like(flat) * 1e-6
    toks = jnp.asarray(
        np.random.default_rng(6).integers(
            0, cfg.vocab, (k, cfg.batch_size, cfg.seq_len + 1)), jnp.int32)
    lrs = jnp.full((k,), 1e-3, jnp.float32)
    out0 = tc(flat, m, v, jnp.asarray(0, jnp.int32), lrs, toks)
    out100 = tc(flat, m, v, jnp.asarray(100, jnp.int32), lrs, toks)
    # Different bias correction => different resulting params.
    assert not np.allclose(np.asarray(out0[0]), np.asarray(out100[0]))
