"""AOT pipeline: manifest structure, HLO text emission, rebuild stamping."""

import json
import os

import pytest

from compile import aot, configs, model

CFG = configs.BY_NAME["m75a"]


def test_manifest_structure():
    man = aot.build_manifest(CFG)
    assert man["schema_version"] == 1
    assert man["n_params"] == model.n_params(CFG)
    assert man["config"]["name"] == "m75a"
    assert man["config"]["head_dim"] == CFG.head_dim
    names = [p["name"] for p in man["params"]]
    assert names[0] == "wte" and names[-1] == "ln_f_g"
    # Offsets contiguous and sizes match shapes.
    off = 0
    for p in man["params"]:
        assert p["offset"] == off
        size = 1
        for s in p["shape"]:
            size *= s
        assert p["size"] == size
        off += size
    assert off == man["n_params"]


def test_manifest_signatures():
    man = aot.build_manifest(CFG)
    ts = man["steps"]["train_step"]
    assert [i["name"] for i in ts["inputs"]] == [
        "params", "m", "v", "step", "lr", "tokens"]
    assert [o["name"] for o in ts["outputs"]] == [
        "params", "m", "v", "loss", "grad_norm", "update_norm", "act_norm"]
    assert ts["inputs"][0]["shape"] == [model.n_params(CFG)]
    assert ts["inputs"][5]["shape"] == [CFG.batch_size, CFG.seq_len + 1]
    assert man["steps"]["eval_step"]["file"] == "eval_step.hlo.txt"
    sc = man["steps"]["score_step"]
    assert sc["inputs"][2]["shape"] == [CFG.batch_size, CFG.seq_len]


def test_manifest_json_serializable():
    for cfg in configs.CONFIGS:
        json.dumps(aot.build_manifest(cfg))


def test_hlo_text_emission():
    import jax
    fn = model.step_fns(CFG)["eval_step"]
    lowered = jax.jit(fn).lower(*model.example_args(CFG, "eval_step"))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f32[%d]" % model.n_params(CFG) in text


def test_compile_config_stamps_and_skips(tmp_path):
    fp = aot._source_fingerprint()
    did = aot.compile_config(CFG, str(tmp_path), fp)
    assert did
    for f in ("train_step.hlo.txt", "eval_step.hlo.txt",
              "score_step.hlo.txt", "manifest.json", ".stamp"):
        assert (tmp_path / "m75a" / f).exists(), f
    # Second run is a no-op; changed fingerprint forces a rebuild.
    assert not aot.compile_config(CFG, str(tmp_path), fp)
    assert aot.compile_config(CFG, str(tmp_path), "different")


def test_fingerprint_is_stable():
    assert aot._source_fingerprint() == aot._source_fingerprint()


def test_repo_artifacts_exist():
    """`make artifacts` must have produced every config (integration pin)."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(root):
        pytest.skip("artifacts not built yet")
    idx = json.load(open(os.path.join(root, "index.json")))
    for name in idx["configs"]:
        mdir = os.path.join(root, name)
        man = json.load(open(os.path.join(mdir, "manifest.json")))
        cfg = configs.BY_NAME[name]
        assert man["n_params"] == model.n_params(cfg)
        for step in man["steps"].values():
            assert os.path.exists(os.path.join(mdir, step["file"]))
