"""L2: the paper's local-training compute graph in JAX.

An MPT-style decoder-only transformer (pre-LN, ALiBi causal attention, 4x
GELU MLP, weight-tied LM head -- paper section 6.1) plus the fused local
train step the Photon LLM Node executes: forward, backward, global-norm
gradient clipping, and an AdamW update with the paper's (0.9, 0.95) betas.

All parameters live in ONE flat f32 vector. The layout (name/shape/offset per
tensor) is exported to `manifest.json` by aot.py so the Rust coordinator can
initialize, aggregate, and inspect per-tensor norms without ever re-deriving
model structure. Inside the jitted step the flat vector is sliced with static
offsets, so XLA sees ordinary fused tensor code.

Exported step functions (lowered to HLO text per config by aot.py):

  train_step(params, m, v, step, lr, tokens[B, l+1])
      -> (params', m', v', loss, grad_norm, update_norm, act_norm)
  eval_step(params, tokens[B, l+1]) -> (sum_nll, token_count)
  score_step(params, tokens[B, l+1], mask[B, l]) -> (option_ll[B], option_len[B])

The attention inner op is either the pure-jnp reference (fast on XLA-CPU) or
the L1 Pallas flash kernel (cfg.attn_impl == "pallas"), which lowers into the
same HLO via interpret mode. Both are asserted numerically equal in tests.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels.ref import attention_ref, alibi_slopes
from .kernels.flash_attention import flash_attention_trainable


# --------------------------------------------------------------------------
# Flat parameter layout
# --------------------------------------------------------------------------

def layout(cfg: ModelConfig):
    """[(name, shape, init_spec)] in flat-vector order.

    init_spec is one of {"kind": "normal", "std": s} / {"kind": "ones"} and is
    consumed by the Rust initializer (model/init.rs). Residual-output
    projections use the GPT-2 / MPT depth-scaled std 0.02/sqrt(2*n_blocks).
    """
    d, mlp, v = cfg.d_model, cfg.mlp_dim, cfg.vocab
    std = 0.02
    resid_std = 0.02 / float(np.sqrt(2.0 * cfg.n_blocks))
    ents = [("wte", (v, d), {"kind": "normal", "std": std})]
    for b in range(cfg.n_blocks):
        p = f"block{b}."
        ents += [
            (p + "ln1_g", (d,), {"kind": "ones"}),
            (p + "w_qkv", (d, 3 * d), {"kind": "normal", "std": std}),
            (p + "w_o", (d, d), {"kind": "normal", "std": resid_std}),
            (p + "ln2_g", (d,), {"kind": "ones"}),
            (p + "w_up", (d, mlp), {"kind": "normal", "std": std}),
            (p + "w_down", (mlp, d), {"kind": "normal", "std": resid_std}),
        ]
    ents.append(("ln_f_g", (d,), {"kind": "ones"}))
    return ents


def layout_with_offsets(cfg: ModelConfig):
    """[(name, shape, offset, size, init_spec)] plus total parameter count."""
    out, off = [], 0
    for name, shape, init in layout(cfg):
        size = int(np.prod(shape))
        out.append((name, shape, off, size, init))
        off += size
    return out, off


def n_params(cfg: ModelConfig) -> int:
    return layout_with_offsets(cfg)[1]


def unpack(flat, cfg: ModelConfig):
    """Flat vector -> {name: tensor} via static slices (fuses under jit)."""
    ents, total = layout_with_offsets(cfg)
    assert flat.shape == (total,), (flat.shape, total)
    return {
        name: flat[off: off + size].reshape(shape)
        for name, shape, off, size, _ in ents
    }


def pack(params: dict, cfg: ModelConfig):
    """{name: tensor} -> flat vector; inverse of `unpack` (tested)."""
    ents, _ = layout_with_offsets(cfg)
    return jnp.concatenate(
        [params[name].reshape(-1) for name, *_ in ents])


def decay_mask(cfg: ModelConfig) -> np.ndarray:
    """1.0 where AdamW weight decay applies (matrices), 0.0 for LN scales."""
    ents, total = layout_with_offsets(cfg)
    mask = np.zeros(total, np.float32)
    for name, shape, off, size, _ in ents:
        if len(shape) > 1:  # decay weights, not LN gains
            mask[off: off + size] = 1.0
    return mask


def init_params_np(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    """Numpy initializer (used in python tests; Rust has its own PCG-based
    initializer following the same per-tensor init specs)."""
    rng = np.random.default_rng(seed)
    ents, total = layout_with_offsets(cfg)
    flat = np.zeros(total, np.float32)
    for _name, _shape, off, size, init in ents:
        if init["kind"] == "normal":
            flat[off: off + size] = (
                rng.standard_normal(size) * init["std"]).astype(np.float32)
        elif init["kind"] == "ones":
            flat[off: off + size] = 1.0
        else:  # pragma: no cover
            raise ValueError(init)
    return flat


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _ln(x, g):
    """LayerNorm with scale only (bias-free, as in our MPT reduction)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g


def _attention(x, w_qkv, w_o, cfg: ModelConfig):
    b, l, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    qkv = x @ w_qkv  # [B, L, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    slopes = alibi_slopes(h)
    if cfg.attn_impl == "pallas":
        # Blocks sized to tile the (small) analogue sequence lengths; on a
        # real TPU these would be 128/128 (see flash_attention.py docstring).
        bq = min(128, l)
        o = flash_attention_trainable(q, k, v, slopes, bq, bq)
    else:
        o = attention_ref(q, k, v, slopes)
    o = o.transpose(0, 2, 1, 3).reshape(b, l, d)
    return o @ w_o


def forward(flat, tokens, cfg: ModelConfig):
    """tokens [B, L] int32 -> (logits [B, L, V], act_norm scalar).

    act_norm is the l2 norm of the final-layer output activations -- the
    divergence leading-indicator tracked in the paper's fig5 (OPT-style
    monitoring, section 6.2).
    """
    p = unpack(flat, cfg)
    x = p["wte"][tokens]  # [B, L, d]
    for bidx in range(cfg.n_blocks):
        blk = f"block{bidx}."
        a = _attention(_ln(x, p[blk + "ln1_g"]),
                       p[blk + "w_qkv"], p[blk + "w_o"], cfg)
        x = x + a
        hmid = _ln(x, p[blk + "ln2_g"])
        m = jax.nn.gelu(hmid @ p[blk + "w_up"]) @ p[blk + "w_down"]
        x = x + m
    x = _ln(x, p["ln_f_g"])
    act_norm = jnp.sqrt(jnp.sum(x * x))
    logits = x @ p["wte"].T  # weight-tied head
    return logits, act_norm


def _nll(logits, targets):
    """Per-position negative log likelihood, [B, L]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return logz - gold


def loss_fn(flat, tokens_in, targets, cfg: ModelConfig):
    logits, act_norm = forward(flat, tokens_in, cfg)
    return jnp.mean(_nll(logits, targets)), act_norm


# --------------------------------------------------------------------------
# Step functions (AOT entry points)
# --------------------------------------------------------------------------

def train_step(flat, m, v, step, lr, tokens, *, cfg: ModelConfig):
    """One local AdamW step (fwd+bwd+clip+update), fully fused under jit.

    `step` is the 1-based optimizer step (for bias correction); `lr` comes
    from the Rust-side cosine scheduler (paper: schedule synchronized across
    *sequential* steps, Table 3), so the artifact stays schedule-agnostic.
    """
    tokens_in, targets = tokens[:, :-1], tokens[:, 1:]
    (loss, act_norm), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        flat, tokens_in, targets, cfg)

    grad_norm = jnp.sqrt(jnp.sum(grads * grads))
    clip_coef = jnp.minimum(1.0, cfg.clip_norm / (grad_norm + 1e-6))
    grads = grads * clip_coef

    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    m_new = b1 * m + (1.0 - b1) * grads
    v_new = b2 * v + (1.0 - b2) * grads * grads
    stepf = step.astype(jnp.float32)
    m_hat = m_new / (1.0 - b1 ** stepf)
    v_hat = v_new / (1.0 - b2 ** stepf)
    mask = jnp.asarray(decay_mask(cfg))
    update = lr * (m_hat / (jnp.sqrt(v_hat) + eps)
                   + cfg.weight_decay * mask * flat)
    flat_new = flat - update
    update_norm = jnp.sqrt(jnp.sum(update * update))
    return (flat_new, m_new, v_new, loss, grad_norm, update_norm, act_norm)


#: Local steps fused into one `train_chunk` dispatch (perf pass, DESIGN.md
#: §7): amortizes PJRT dispatch + host<->device parameter round-trips over
#: CHUNK steps via `lax.scan`. Rust falls back to `train_step` for the
#: remainder when τ is not a multiple of CHUNK.
TRAIN_CHUNK = 8


def train_chunk(flat, m, v, step0, lrs, tokens, *, cfg: ModelConfig):
    """CHUNK fused local AdamW steps under one jit (lax.scan).

    step0: optimizer step count *before* this chunk (0-based); lrs: [CHUNK]
    learning rates from the Rust scheduler; tokens: [CHUNK, B, l+1].
    Numerically identical to CHUNK calls of `train_step` (tested).
    Returns per-step metric vectors so the coordinator's monitoring keeps
    per-step resolution.
    """
    mask = jnp.asarray(decay_mask(cfg))
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps

    def body(carry, xs):
        flat, m, v = carry
        toks, lr, stepf = xs
        tokens_in, targets = toks[:, :-1], toks[:, 1:]
        (loss, act_norm), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            flat, tokens_in, targets, cfg)
        grad_norm = jnp.sqrt(jnp.sum(grads * grads))
        clip_coef = jnp.minimum(1.0, cfg.clip_norm / (grad_norm + 1e-6))
        grads = grads * clip_coef
        m_new = b1 * m + (1.0 - b1) * grads
        v_new = b2 * v + (1.0 - b2) * grads * grads
        m_hat = m_new / (1.0 - b1 ** stepf)
        v_hat = v_new / (1.0 - b2 ** stepf)
        update = lr * (m_hat / (jnp.sqrt(v_hat) + eps)
                       + cfg.weight_decay * mask * flat)
        flat_new = flat - update
        update_norm = jnp.sqrt(jnp.sum(update * update))
        return (flat_new, m_new, v_new), (loss, grad_norm, update_norm, act_norm)

    steps = step0.astype(jnp.float32) + 1.0 + jnp.arange(
        TRAIN_CHUNK, dtype=jnp.float32)
    (flat, m, v), (losses, gns, uns, ans) = jax.lax.scan(
        body, (flat, m, v), (tokens, lrs, steps))
    return (flat, m, v, losses, gns, uns, ans)


def eval_step(flat, tokens, *, cfg: ModelConfig):
    """Summed NLL + token count over a batch; Rust turns sums into ppl."""
    tokens_in, targets = tokens[:, :-1], tokens[:, 1:]
    logits, _ = forward(flat, tokens_in, cfg)
    nll = _nll(logits, targets)
    return (jnp.sum(nll), jnp.asarray(nll.size, jnp.float32))


def score_step(flat, tokens, mask, *, cfg: ModelConfig):
    """Masked per-sequence log-likelihood (downstream eval harness, §7.9).

    mask [B, L] selects the *target* positions belonging to the scored
    continuation; returns (total logprob per sequence, #scored tokens) so the
    harness can apply length normalization like the paper's ICL suite.
    """
    tokens_in, targets = tokens[:, :-1], tokens[:, 1:]
    logits, _ = forward(flat, tokens_in, cfg)
    ll = -_nll(logits, targets) * mask
    return (jnp.sum(ll, axis=1), jnp.sum(mask, axis=1))


def step_fns(cfg: ModelConfig):
    """The three AOT entry points with the config closed over."""
    return {
        "train_step": functools.partial(train_step, cfg=cfg),
        "train_chunk": functools.partial(train_chunk, cfg=cfg),
        "eval_step": functools.partial(eval_step, cfg=cfg),
        "score_step": functools.partial(score_step, cfg=cfg),
    }


def example_args(cfg: ModelConfig, which: str):
    """ShapeDtypeStructs matching each entry point's signature."""
    total = n_params(cfg)
    f32, i32 = jnp.float32, jnp.int32
    vec = jax.ShapeDtypeStruct((total,), f32)
    toks = jax.ShapeDtypeStruct((cfg.batch_size, cfg.seq_len + 1), i32)
    if which == "train_step":
        return (vec, vec, vec, jax.ShapeDtypeStruct((), i32),
                jax.ShapeDtypeStruct((), f32), toks)
    if which == "train_chunk":
        return (
            vec, vec, vec, jax.ShapeDtypeStruct((), i32),
            jax.ShapeDtypeStruct((TRAIN_CHUNK,), f32),
            jax.ShapeDtypeStruct(
                (TRAIN_CHUNK, cfg.batch_size, cfg.seq_len + 1), i32),
        )
    if which == "eval_step":
        return (vec, toks)
    if which == "score_step":
        return (vec, toks,
                jax.ShapeDtypeStruct((cfg.batch_size, cfg.seq_len), f32))
    raise ValueError(which)
