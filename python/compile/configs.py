"""Model-configuration ladder for the Photon reproduction.

Each entry is a scaled-down **analogue** of one row of the paper's Table 2
(75M..7B MPT models). The structure is preserved exactly -- decoder-only,
pre-LN, ALiBi attention, 4x GELU MLP, weight-tied LM head, AdamW(0.9, 0.95)
-- while vocabulary/width/depth are reduced so the full federated experiment
grid runs on a CPU PJRT backend. See DESIGN.md section 1 for the substitution
argument.

The ladder spans ~250x in parameter count (the paper's spans ~100x), which is
what the scaling claims (fig3/fig9, consensus-vs-size) are asserted against.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Architecture + local-training hyperparameters for one ladder entry."""

    name: str
    paper_alias: str  # which paper model this row is the analogue of
    vocab: int
    d_model: int
    n_heads: int
    n_blocks: int
    seq_len: int
    batch_size: int
    # Local (inner) optimizer: AdamW, following paper Table 2/3.
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # Attention lowering: "jnp" = fused reference (fast under XLA-CPU),
    # "pallas" = the L1 flash kernel in interpret mode (bit-compared in tests).
    attn_impl: str = "jnp"

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def mlp_dim(self) -> int:
        return 4 * self.d_model  # expansion ratio 4, paper Table 2

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["mlp_dim"] = self.mlp_dim
        return d


def _c(name, alias, vocab, d, h, blocks, seq, batch, **kw) -> ModelConfig:
    return ModelConfig(
        name=name, paper_alias=alias, vocab=vocab, d_model=d, n_heads=h,
        n_blocks=blocks, seq_len=seq, batch_size=batch, **kw,
    )


# The experiment ladder. Names are referenced from rust/src/config/mod.rs;
# keep them in sync.
CONFIGS = [
    _c("m75a", "75M", 256, 32, 2, 2, 32, 4),
    _c("m125a", "125M", 256, 48, 4, 3, 32, 4),
    _c("m350a", "350M", 256, 64, 4, 4, 32, 4),
    _c("m1ba", "1.3B", 512, 96, 6, 6, 32, 4),
    _c("m3ba", "3B", 512, 128, 8, 8, 32, 4),
    _c("m7ba", "7B", 512, 192, 12, 10, 32, 4),
    # Small-local-batch variant for the fig10 outer-optimizer ablation.
    _c("m125a_b2", "125M (small batch)", 256, 48, 4, 3, 32, 2),
    # Same architecture as m75a but lowered through the L1 Pallas kernel;
    # proves the pallas -> HLO -> rust path end to end.
    _c("tiny_pallas", "75M (pallas)", 256, 32, 2, 2, 32, 4, attn_impl="pallas"),
    # End-to-end driver model (examples/e2e_pretrain.rs): ~5M params.
    _c("e2e", "e2e-5M", 1024, 256, 8, 8, 64, 8),
]

BY_NAME = {c.name: c for c in CONFIGS}


def param_count(cfg: ModelConfig) -> int:
    """Total trainable parameters (tied LM head => embedding counted once)."""
    per_block = (
        cfg.d_model  # ln1 scale
        + cfg.d_model * 3 * cfg.d_model  # qkv
        + cfg.d_model * cfg.d_model  # out proj
        + cfg.d_model  # ln2 scale
        + cfg.d_model * cfg.mlp_dim  # mlp up
        + cfg.mlp_dim * cfg.d_model  # mlp down
    )
    return cfg.vocab * cfg.d_model + cfg.n_blocks * per_block + cfg.d_model
