"""L1 Pallas kernel: FlashAttention-style causal ALiBi attention for TPU.

Hardware adaptation (paper trains with FlashAttention on NVIDIA GPUs; see
DESIGN.md section "Hardware-Adaptation"): the GPU threadblock tiling becomes a
Pallas grid over (batch*heads, query blocks); K/V stream through VMEM in
`block_k` slabs; the online-softmax running state (m, l, acc) lives in the
kernel's loop carry (the TPU analogue of registers/shared memory); the ALiBi
bias and the causal mask are *computed* from iota on the score tile, never
materialized in HBM. Matmul tiles are (block_q x d) @ (d x block_k) and
(block_q x block_k) @ (block_k x d), MXU-friendly at block 128.

On this image the kernel runs under `interpret=True` (the CPU PJRT plugin
cannot execute Mosaic custom-calls); real-TPU performance is *estimated* in
DESIGN.md from the VMEM footprint below. Correctness is asserted against
`ref.attention_ref` by python/tests/test_kernel.py (hypothesis sweeps) and via
the `tiny_pallas` artifact executed from Rust.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30  # finite stand-in for -inf: keeps exp() exact zeros, no NaNs


def _flash_kernel(q_ref, k_ref, v_ref, slope_ref, o_ref, *, block_q: int,
                  block_k: int, seq_len: int, head_dim: int):
    """One grid step: query block `jq` of flattened batch-head row `bh`.

    Refs (VMEM blocks):
      q_ref     [1, block_q, D]    query tile for this grid cell
      k_ref     [1, L, D]          full K row for this bh (streamed in slabs)
      v_ref     [1, L, D]          full V row
      slope_ref [1]                ALiBi slope of this head
      o_ref     [1, block_q, D]    output tile
    """
    jq = pl.program_id(1)
    q = q_ref[0, :, :].astype(jnp.float32)  # [bq, D]
    slope = slope_ref[0].astype(jnp.float32)
    scale = (1.0 / (head_dim ** 0.5)).__float__()

    q_idx = jq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    # Causality: only K blocks with start <= last query index contribute.
    n_kblocks = (jq * block_q + block_q + block_k - 1) // block_k

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        start = kb * block_k
        k_tile = pl.load(k_ref, (0, pl.dslice(start, block_k), slice(None)))
        v_tile = pl.load(v_ref, (0, pl.dslice(start, block_k), slice(None)))
        k_tile = k_tile.astype(jnp.float32)
        v_tile = v_tile.astype(jnp.float32)

        # [bq, bk] score tile on the MXU: (bq x D) @ (D x bk).
        s = jax.lax.dot_general(
            q, k_tile, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        k_idx = start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        dist = (q_idx - k_idx).astype(jnp.float32)
        s = s - slope * dist  # ALiBi, fused into the tile
        s = jnp.where(q_idx >= k_idx, s, NEG_INF)  # causal mask, from iota

        # Online softmax update (Milakov & Gimelshein / FlashAttention).
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])  # [bq, bk]
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p, v_tile, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc = acc * alpha[:, None] + pv
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, head_dim), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _m, l = jax.lax.fori_loop(0, n_kblocks, body, (acc0, m0, l0))
    o_ref[0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, slopes, *, block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """Causal ALiBi flash attention. q,k,v: [B, H, L, D]; slopes: [H].

    Returns [B, H, L, D] float32, numerically equal to `ref.attention_ref`.
    Block sizes clamp to the sequence length and must tile it exactly.
    """
    b, h, l, d = q.shape
    block_q = min(block_q, l)
    block_k = min(block_k, l)
    if l % block_q or l % block_k:
        raise ValueError(f"seq_len {l} must be divisible by blocks "
                         f"({block_q}, {block_k})")
    bh = b * h
    qf = q.reshape(bh, l, d)
    kf = k.reshape(bh, l, d)
    vf = v.reshape(bh, l, d)
    slopes_f = jnp.tile(jnp.asarray(slopes, jnp.float32), b)  # [BH]

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k,
        seq_len=l, head_dim=d)
    out = pl.pallas_call(
        kernel,
        grid=(bh, l // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, l, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, l, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, d), jnp.float32),
        interpret=interpret,
    )(qf, kf, vf, slopes_f)
    return out.reshape(b, h, l, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def flash_attention_trainable(q, k, v, slopes, block_q=128, block_k=128):
    """Differentiable wrapper used by the L2 model when attn_impl="pallas".

    Forward: the Pallas flash kernel above (lowered into the step HLO).
    Backward: recompute-based VJP through the fused reference formulation --
    the same recompute-instead-of-store strategy FlashAttention's backward
    pass uses, expressed at the XLA level. (A hand-tiled Pallas backward
    kernel is a possible extension; numerics are identical either way and the
    forward hot-spot is what the paper's recipe accelerates.)
    """
    return flash_attention(q, k, v, slopes, block_q=block_q, block_k=block_k,
                           interpret=True)


def _fat_fwd(q, k, v, slopes, block_q, block_k):
    out = flash_attention(q, k, v, slopes, block_q=block_q, block_k=block_k,
                          interpret=True)
    return out, (q, k, v, slopes)


def _fat_bwd(block_q, block_k, res, g):
    from .ref import attention_ref  # local import avoids a cycle
    q, k, v, slopes = res
    _out, vjp = jax.vjp(lambda q_, k_, v_: attention_ref(q_, k_, v_, slopes),
                        q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None


flash_attention_trainable.defvjp(_fat_fwd, _fat_bwd)


def vmem_footprint_bytes(block_q: int, block_k: int, seq_len: int,
                         head_dim: int) -> int:
    """Estimated VMEM bytes for one grid cell (used by DESIGN.md perf notes).

    q tile + streamed k/v slabs (double-buffered) + score tile + softmax state
    + accumulator, all f32.
    """
    f = 4
    q_t = block_q * head_dim
    kv = 2 * 2 * block_k * head_dim  # two tensors, double buffered
    s_t = block_q * block_k
    state = block_q * (2 + head_dim)
    return f * (q_t + kv + s_t + state)
