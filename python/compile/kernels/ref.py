"""Pure-jnp oracle for the L1 attention kernel.

This file is the CORRECTNESS REFERENCE: the Pallas flash-attention kernel in
`flash_attention.py` must match `attention_ref` to float32 tolerance for every
shape/dtype the tests sweep (see python/tests/test_kernel.py). It is also the
"jnp" attention lowering used by the fast CPU artifacts (XLA fuses it well).

Semantics reproduced from the paper's local training recipe (MPT + ALiBi +
causal masking, section 6.1):

  scores[b,h,i,j] = q . k / sqrt(d_head)  -  slope_h * (i - j)   for j <= i
  out = softmax(scores) @ v
"""

import jax.numpy as jnp
import numpy as np


def alibi_slopes(n_heads: int) -> np.ndarray:
    """ALiBi head slopes (Press et al. 2022): geometric 2^(-8i/n) sequence.

    For non-power-of-two head counts we follow the reference implementation:
    use the slopes for the next power of two and take the odd-indexed extras.
    """
    def pow2_slopes(n):
        start = 2.0 ** (-(2.0 ** -(np.log2(n) - 3)))
        return np.array([start ** (i + 1) for i in range(n)])

    if np.log2(n_heads).is_integer():
        return pow2_slopes(n_heads).astype(np.float32)
    closest = 2 ** int(np.floor(np.log2(n_heads)))
    base = pow2_slopes(closest)
    extra = pow2_slopes(2 * closest)[0::2][: n_heads - closest]
    return np.concatenate([base, extra]).astype(np.float32)


def alibi_bias(slopes: jnp.ndarray, seq_len: int) -> jnp.ndarray:
    """[H, L, L] additive bias: -slope * (i - j), lower triangle only."""
    i = jnp.arange(seq_len)[:, None]
    j = jnp.arange(seq_len)[None, :]
    dist = (i - j).astype(jnp.float32)  # >= 0 on/below the diagonal
    return -slopes[:, None, None] * dist[None, :, :]


def attention_ref(q, k, v, slopes):
    """Causal ALiBi attention. q,k,v: [B, H, L, D]; slopes: [H].

    Returns [B, H, L, D] in float32.
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    b, h, l, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    scores = scores + alibi_bias(jnp.asarray(slopes, jnp.float32), l)[None]
    causal = jnp.tril(jnp.ones((l, l), dtype=bool))
    scores = jnp.where(causal[None, None], scores, -jnp.inf)
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", weights, v)
