"""AOT pipeline: lower every model config's step functions to HLO *text*.

This is the single build-time python entry point (`make artifacts`). For each
config in configs.CONFIGS it emits

    artifacts/<name>/train_step.hlo.txt
    artifacts/<name>/eval_step.hlo.txt
    artifacts/<name>/score_step.hlo.txt
    artifacts/<name>/manifest.json

The interchange format is HLO TEXT, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Lowering uses `return_tuple=True`; the Rust runtime unwraps the tuple.

manifest.json carries everything the Rust coordinator needs to drive the
artifacts blind: the model config, the flat-parameter layout (name / shape /
offset / size / per-tensor init spec), and the exact I/O signature of each
step. Rust parses it with its own JSON parser (rust/src/util/json.rs).

Usage:
    python -m compile.aot --out-dir ../artifacts [--config NAME ...] [--force]
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import configs, model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (xla 0.5.1-compatible)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(cfg: configs.ModelConfig, which: str):
    """Human/machine-readable I/O signature recorded in the manifest."""
    n = model.n_params(cfg)
    k = model.TRAIN_CHUNK
    vec = {"dtype": "f32", "shape": [n]}
    toks = {"dtype": "i32", "shape": [cfg.batch_size, cfg.seq_len + 1]}
    ktoks = {"dtype": "i32", "shape": [k, cfg.batch_size, cfg.seq_len + 1]}
    kf = {"dtype": "f32", "shape": [k]}
    scalar_f = {"dtype": "f32", "shape": []}
    scalar_i = {"dtype": "i32", "shape": []}
    batch_f = {"dtype": "f32", "shape": [cfg.batch_size]}
    mask = {"dtype": "f32", "shape": [cfg.batch_size, cfg.seq_len]}
    if which == "train_step":
        return {
            "inputs": [
                {"name": "params", **vec}, {"name": "m", **vec},
                {"name": "v", **vec}, {"name": "step", **scalar_i},
                {"name": "lr", **scalar_f}, {"name": "tokens", **toks},
            ],
            "outputs": [
                {"name": "params", **vec}, {"name": "m", **vec},
                {"name": "v", **vec}, {"name": "loss", **scalar_f},
                {"name": "grad_norm", **scalar_f},
                {"name": "update_norm", **scalar_f},
                {"name": "act_norm", **scalar_f},
            ],
        }
    if which == "train_chunk":
        return {
            "inputs": [
                {"name": "params", **vec}, {"name": "m", **vec},
                {"name": "v", **vec}, {"name": "step0", **scalar_i},
                {"name": "lrs", **kf}, {"name": "tokens", **ktoks},
            ],
            "outputs": [
                {"name": "params", **vec}, {"name": "m", **vec},
                {"name": "v", **vec}, {"name": "losses", **kf},
                {"name": "grad_norms", **kf}, {"name": "update_norms", **kf},
                {"name": "act_norms", **kf},
            ],
        }
    if which == "eval_step":
        return {
            "inputs": [{"name": "params", **vec}, {"name": "tokens", **toks}],
            "outputs": [{"name": "sum_nll", **scalar_f},
                        {"name": "token_count", **scalar_f}],
        }
    if which == "score_step":
        return {
            "inputs": [{"name": "params", **vec}, {"name": "tokens", **toks},
                       {"name": "mask", **mask}],
            "outputs": [{"name": "option_ll", **batch_f},
                        {"name": "option_len", **batch_f}],
        }
    raise ValueError(which)


def build_manifest(cfg: configs.ModelConfig) -> dict:
    ents, total = model.layout_with_offsets(cfg)
    return {
        "schema_version": 1,
        "config": cfg.to_dict(),
        "n_params": total,
        "params": [
            {"name": name, "shape": list(shape), "offset": off,
             "size": size, "init": init}
            for name, shape, off, size, init in ents
        ],
        "train_chunk_size": model.TRAIN_CHUNK,
        "steps": {
            which: {"file": f"{which}.hlo.txt", **_sig(cfg, which)}
            for which in ("train_step", "train_chunk", "eval_step",
                          "score_step")
        },
    }


def _source_fingerprint() -> str:
    """Hash of the compile-path sources; artifacts rebuild when these change."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _dirs, files in sorted(os.walk(here)):
        if "__pycache__" in root:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def compile_config(cfg: configs.ModelConfig, out_dir: str, fingerprint: str,
                   force: bool = False) -> bool:
    """Lower one config; returns True if work was done."""
    cdir = os.path.join(out_dir, cfg.name)
    stamp = os.path.join(cdir, ".stamp")
    if not force and os.path.exists(stamp):
        with open(stamp) as fh:
            if fh.read().strip() == fingerprint:
                print(f"[aot] {cfg.name}: up to date")
                return False
    os.makedirs(cdir, exist_ok=True)
    fns = model.step_fns(cfg)
    t0 = time.time()
    for which, fn in fns.items():
        args = model.example_args(cfg, which)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(os.path.join(cdir, f"{which}.hlo.txt"), "w") as fh:
            fh.write(text)
        print(f"[aot] {cfg.name}/{which}: {len(text)} chars "
              f"({time.time() - t0:.1f}s)")
    with open(os.path.join(cdir, "manifest.json"), "w") as fh:
        json.dump(build_manifest(cfg), fh, indent=1)
    with open(stamp, "w") as fh:
        fh.write(fingerprint)
    return True


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", action="append", default=None,
                    help="config name(s) to build; default: all")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    todo = configs.CONFIGS
    if args.config:
        unknown = set(args.config) - set(configs.BY_NAME)
        if unknown:
            print(f"unknown configs: {sorted(unknown)}", file=sys.stderr)
            return 1
        todo = [configs.BY_NAME[n] for n in args.config]

    fingerprint = _source_fingerprint()
    os.makedirs(args.out_dir, exist_ok=True)
    for cfg in todo:
        compile_config(cfg, args.out_dir, fingerprint, force=args.force)
    # Top-level index so the Rust side can discover configs without listing
    # directories (and so `make -q artifacts` has a single sentinel).
    with open(os.path.join(args.out_dir, "index.json"), "w") as fh:
        json.dump({
            "fingerprint": fingerprint,
            "configs": [c.name for c in configs.CONFIGS],
        }, fh, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
