#!/usr/bin/env python3
"""Regenerate the codec golden vectors in rust/tests/fixtures/codec/.

An independent, from-scratch reimplementation of the Rust side's
xoshiro256** RNG (rust/src/util/rng.rs), `testkit::rand_vec`, and the
q8/q4 stochastic-rounding encoders (rust/src/compress/quant.rs), emitting
the exact wire bodies. `rust/tests/props_perf.rs` pins the Rust encoders
byte-for-byte against these files, so a change to the draw schedule, scale
arithmetic, or body layout — accidental or deliberate — fails loudly in
two implementations at once.

f32 semantics are emulated with `struct.pack('<f')` round-trips: every
Rust f32 operation here is a single binary op computed in f64 and then
rounded, which is exact (f64 carries more than 2x24+2 significand bits,
so no double-rounding error is possible).

Usage: python3 tools/gen_golden_vectors.py   (from the repo root; writes
fixture .bin files and prints a manifest — commit both sides together.)
"""

import os
import struct

MASK = (1 << 64) - 1
CODEC_Q8, CODEC_Q4 = 2, 3
FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "fixtures", "codec")


def f32(x):
    """Round a Python float (f64) to the nearest f32, returned as f64."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


def splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return state, z ^ (z >> 31)


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """xoshiro256** seeded via SplitMix64 — mirrors rust/src/util/rng.rs."""

    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm, v = splitmix64(sm)
            s.append(v)
        self.s = s

    def next_u64(self):
        s = self.s
        r = (rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return r

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def f32(self):
        return f32(self.f64())


def rand_vec(rng, n, scale):
    """testkit::rand_vec — (rng.f32() * 2.0 - 1.0) * scale, each op in f32.
    `scale` is first rounded to f32, matching the Rust call site's literal."""
    s = f32(scale)
    return [f32(f32(f32(rng.f32() * 2.0) - 1.0) * s) for _ in range(n)]


def block_scales(delta, block, levels):
    scales = []
    for lo in range(0, len(delta), block):
        mx = 0.0
        for x in delta[lo:lo + block]:
            mx = max(mx, abs(x))  # f32 abs/max are exact — no rounding
        scales.append(f32(mx / levels))
    return scales


def quantize(x, scale, rng):
    """One stochastic-rounding step (caller clamps). x, scale are exact f32
    values held as f64, so the division and floor match Rust bit-for-bit."""
    t = x / scale
    f = t // 1.0  # == floor for finite t
    q = int(f)
    if rng.f64() < t - f:
        q += 1
    return q


def header(codec_id, block, n):
    return bytes([codec_id]) + struct.pack("<I", block) + struct.pack("<Q", n)


def encode_q8(delta, block, seed):
    block = max(block, 1)
    scales = block_scales(delta, block, 127.0)
    out = bytearray(header(CODEC_Q8, block, len(delta)))
    for s in scales:
        out += struct.pack("<f", s)
    rng = Rng(seed)
    for bi, s in enumerate(scales):
        ch = delta[bi * block:(bi + 1) * block]
        if s <= 0.0:
            out += bytes(len(ch))  # zero block: q = 0, no rounding draws
            continue
        for x in ch:
            q = max(-127, min(127, quantize(x, s, rng)))
            out.append(q & 0xFF)
    return bytes(out)


def encode_q4(delta, block, seed):
    block = max(block, 1)
    scales = block_scales(delta, block, 7.0)
    out = bytearray(header(CODEC_Q4, block, len(delta)))
    for s in scales:
        out += struct.pack("<f", s)
    rng = Rng(seed)
    pending = None  # low nibble threads across block boundaries
    for bi, s in enumerate(scales):
        ch = delta[bi * block:(bi + 1) * block]
        for x in ch:
            if s <= 0.0:
                nib = 8  # q = 0, no draw
            else:
                nib = max(-7, min(7, quantize(x, s, rng))) + 8
            if pending is None:
                pending = nib
            else:
                out.append(pending | (nib << 4))
                pending = None
    if pending is not None:
        out.append(pending | (8 << 4))  # odd n: pad nibble 8
    return bytes(out)


# (name, codec, n, block, rand_vec scale, rand_vec seed, encode seed).
# Shapes cover lane remainders, a ragged final block, and odd n (q4 pad);
# props_perf.rs regenerates each delta with the same (seed, n, scale) and
# must reproduce these bytes through the public UpdateCodec API.
CASES = [
    ("q8_n96_b16", "q8", 96, 16, 0.05, 1001, 42),
    ("q8_n101_b16", "q8", 101, 16, 0.05, 1002, 43),
    ("q4_n64_b8", "q4", 64, 8, 0.05, 1003, 44),
    ("q4_n33_b8", "q4", 33, 8, 0.05, 1004, 45),
]


def main():
    os.makedirs(FIXTURE_DIR, exist_ok=True)
    for name, codec, n, block, scale, vec_seed, enc_seed in CASES:
        delta = rand_vec(Rng(vec_seed), n, scale)
        body = (encode_q8 if codec == "q8" else encode_q4)(delta, block, enc_seed)
        path = os.path.join(FIXTURE_DIR, f"{name}.bin")
        with open(path, "wb") as f:
            f.write(body)
        print(f"{name}: n={n} block={block} vec_seed={vec_seed} "
              f"enc_seed={enc_seed} -> {len(body)} bytes")
    print(f"fixtures written to {os.path.normpath(FIXTURE_DIR)}")


if __name__ == "__main__":
    main()
