#!/usr/bin/env python3
"""Diff two BENCH_*.json perf snapshots and flag regressions.

Usage:
    tools/bench_compare.py OLD.json NEW.json [--threshold 0.15] [--strict]
    tools/bench_compare.py --self-test

Each snapshot is the array benchkit's Recorder emits: records of
``{bench, iters, mean_ns, p50_ns, p95_ns, units_per_sec, git_rev}``.
Records are matched by ``bench`` name; the regression metric is the
relative change in ``mean_ns`` (new/old - 1), flagged when it exceeds
``--threshold`` (default 0.15, i.e. >15% slower). Benches present on only
one side are reported but never flagged — renames and new benches are not
regressions.

Exit status: 0 unless ``--strict`` is given and at least one regression was
flagged (CI runs non-strict against the committed baselines, since shared
runners are noisy; the trajectory is the artifact, the gate is advisory).
"""

import argparse
import json
import sys

SCHEMA_KEYS = ("bench", "iters", "mean_ns", "p50_ns", "p95_ns", "units_per_sec", "git_rev")


def load(path):
    with open(path) as f:
        data = json.load(f)
    return index(data, path)


def index(data, label):
    if not isinstance(data, list) or not data:
        raise SystemExit(f"{label}: snapshot must be a non-empty JSON array")
    out = {}
    for i, rec in enumerate(data):
        missing = [k for k in SCHEMA_KEYS if k not in rec]
        if missing:
            raise SystemExit(f"{label}: record {i} missing {missing}")
        name = rec["bench"]
        if name in out:
            raise SystemExit(f"{label}: duplicate bench name {name!r}")
        if not (isinstance(rec["mean_ns"], (int, float)) and rec["mean_ns"] > 0):
            raise SystemExit(f"{label}: record {name!r} has non-positive mean_ns")
        out[name] = rec
    return out


def compare(old, new, threshold):
    """Return (report_lines, regressions) comparing two indexed snapshots."""
    lines = []
    regressions = []
    for name in sorted(set(old) | set(new)):
        if name not in new:
            lines.append(f"  {name:<52} only in OLD (removed?)")
            continue
        if name not in old:
            lines.append(f"  {name:<52} only in NEW (added)")
            continue
        o, n = old[name]["mean_ns"], new[name]["mean_ns"]
        delta = n / o - 1.0
        mark = ""
        if delta > threshold:
            mark = "  << REGRESSION"
            regressions.append((name, delta))
        elif delta < -threshold:
            mark = "  (improved)"
        lines.append(
            f"  {name:<52} {o:>14.0f}ns -> {n:>14.0f}ns  {delta:+7.1%}{mark}"
        )
    return lines, regressions


def self_test():
    """Built-in check: a synthetic 2x regression must be flagged and an
    identical pair must pass clean."""
    base = [
        {"bench": "fold/1M", "iters": 10, "mean_ns": 1000.0, "p50_ns": 990.0,
         "p95_ns": 1100.0, "units_per_sec": 1e9, "git_rev": "aaaa"},
        {"bench": "codec/q8", "iters": 10, "mean_ns": 500.0, "p50_ns": 490.0,
         "p95_ns": 600.0, "units_per_sec": 2e9, "git_rev": "aaaa"},
    ]
    slowed = json.loads(json.dumps(base))
    slowed[0]["mean_ns"] = 2000.0  # 2x slower: must be flagged at 15%

    _, regs = compare(index(base, "base"), index(slowed, "slowed"), 0.15)
    assert len(regs) == 1 and regs[0][0] == "fold/1M", f"2x regression not flagged: {regs}"
    assert abs(regs[0][1] - 1.0) < 1e-9, f"wrong delta: {regs[0][1]}"

    _, regs = compare(index(base, "base"), index(base, "base"), 0.15)
    assert regs == [], f"identical snapshots flagged: {regs}"

    # A bench present on only one side is reported, not flagged.
    _, regs = compare(index(base, "base"), index(base[:1], "partial"), 0.15)
    assert regs == [], f"missing bench flagged as regression: {regs}"

    print("bench_compare self-test: ok")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", nargs="?", help="baseline BENCH_*.json")
    ap.add_argument("new", nargs="?", help="candidate BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative mean_ns increase to flag (default 0.15)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any regression is flagged")
    ap.add_argument("--self-test", action="store_true",
                    help="run the built-in synthetic-regression check and exit")
    args = ap.parse_args()

    if args.self_test:
        self_test()
        return

    if not args.old or not args.new:
        ap.error("OLD and NEW snapshot paths are required (or use --self-test)")

    old, new = load(args.old), load(args.new)
    lines, regressions = compare(old, new, args.threshold)
    print(f"bench_compare: {args.old} -> {args.new} (threshold {args.threshold:.0%})")
    for line in lines:
        print(line)
    if regressions:
        print(f"\n{len(regressions)} regression(s) over {args.threshold:.0%}:")
        for name, delta in regressions:
            print(f"  {name}: {delta:+.1%}")
        if args.strict:
            sys.exit(1)
    else:
        print("\nno regressions flagged")


if __name__ == "__main__":
    main()
