#!/usr/bin/env python3
"""Check that intra-repo markdown links resolve.

Scans every tracked *.md file for inline links/images `[text](target)`,
skips external schemes (http/https/mailto), and verifies that

  * the target path exists relative to the linking file (or repo root for
    absolute-style `/`-prefixed targets), and
  * a `#fragment` on a markdown target names a real heading in that file
    (GitHub-style slugs: lowercase, punctuation stripped, spaces->dashes).

Exit status 0 when every link resolves; 1 with a per-link report
otherwise. No dependencies beyond the standard library, so the CI `docs`
job and local runs behave identically:  python3 tools/check_md_links.py
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_DIRS = {".git", "target", "results", "artifacts", "__pycache__", ".venv"}
# Machine-generated reference dumps (arxiv retrievals, issue/changelog
# feeds) are inputs to this repo, not its documentation — their embedded
# figure references never shipped with the text.
SKIP_FILES = {"PAPERS.md", "PAPER.md", "SNIPPETS.md", "ISSUE.md"}

# Inline links/images. Deliberately simple: no reference-style links are
# used in this repo, and nested parens in URLs do not occur.
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def md_files():
    for root, dirs, files in os.walk(REPO):
        dirs[:] = [d for d in sorted(dirs) if d not in SKIP_DIRS]
        for f in sorted(files):
            if f.endswith(".md") and not (root == REPO and f in SKIP_FILES):
                yield os.path.join(root, f)


def github_slug(heading: str) -> str:
    """GitHub's anchor algorithm, close enough for ASCII docs."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)  # strip inline code
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # strip links
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def headings_of(path: str) -> set[str]:
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING_RE.match(line)
            if not m:
                continue
            slug = github_slug(m.group(1))
            n = counts.get(slug, 0)
            counts[slug] = n + 1
            slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def links_of(path: str):
    in_fence = False
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in LINK_RE.finditer(line):
                yield lineno, m.group(1)


def main() -> int:
    errors: list[str] = []
    checked = 0
    for path in md_files():
        rel = os.path.relpath(path, REPO)
        for lineno, target in links_of(path):
            if re.match(r"^[a-zA-Z][a-zA-Z0-9+.-]*:", target):
                continue  # http:, https:, mailto:, etc.
            checked += 1
            raw, _, fragment = target.partition("#")
            if raw:
                base = REPO if raw.startswith("/") else os.path.dirname(path)
                dest = os.path.normpath(os.path.join(base, raw.lstrip("/")))
            else:
                dest = path  # pure-fragment link into this file
            if not os.path.exists(dest):
                errors.append(f"{rel}:{lineno}: dead link {target!r} -> missing {dest}")
                continue
            if fragment and dest.endswith(".md"):
                if fragment not in headings_of(dest):
                    errors.append(
                        f"{rel}:{lineno}: dead anchor {target!r} "
                        f"(no heading slug {fragment!r} in {os.path.relpath(dest, REPO)})"
                    )
    if errors:
        print(f"{len(errors)} dead markdown link(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"markdown link check: {checked} intra-repo links OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
